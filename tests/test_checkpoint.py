"""Checkpoint manager: roundtrip, retention, atomicity, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    r = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(r.normal(0, 1, (8, 4)), jnp.float32),
                       "b": jnp.asarray(r.normal(0, 1, (4,)), jnp.bfloat16)},
            "opt": {"mu": jnp.zeros((8, 4)), "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    cm.save(10, state, extra={"loss": 1.25})
    step, restored, extra = cm.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 10 and extra["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_retention_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _state(s))
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_atomicity_no_tmp_left(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, _state())
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_background_save(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state(), background=True)
    cm.wait()
    assert cm.latest_step() == 1


def test_structure_mismatch_rejected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state())
    bad = {"params": {"w": jnp.zeros((8, 4))}}   # missing leaves
    with pytest.raises(ValueError, match="structure mismatch"):
        cm.restore(bad)


def test_elastic_restore_to_mesh(tmp_path):
    """Restore re-device_puts with the current (1-device) mesh sharding —
    the same code path reshards onto any topology."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh_compat
    cm = CheckpointManager(str(tmp_path))
    state = _state()
    cm.save(3, state)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    specs = {"params": {"w": P("data", "model"), "b": P(None)},
             "opt": {"mu": P("data", None), "step": P()}}
    step, restored, _ = cm.restore(jax.tree.map(jnp.zeros_like, state),
                                   mesh=mesh, specs=specs)
    assert step == 3
    w = restored["params"]["w"]
    assert hasattr(w, "sharding")
    np.testing.assert_array_equal(np.asarray(w), np.asarray(state["params"]["w"]))
