"""Checkpoint manager: roundtrip, retention, atomicity, validation,
corruption fallback, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptError, CheckpointManager


def _state(seed=0):
    r = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(r.normal(0, 1, (8, 4)), jnp.float32),
                       "b": jnp.asarray(r.normal(0, 1, (4,)), jnp.bfloat16)},
            "opt": {"mu": jnp.zeros((8, 4)), "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    cm.save(10, state, extra={"loss": 1.25})
    step, restored, extra = cm.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 10 and extra["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_retention_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _state(s))
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_atomicity_no_tmp_left(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, _state())
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_background_save(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state(), background=True)
    cm.wait()
    assert cm.latest_step() == 1


def test_structure_mismatch_rejected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state())
    bad = {"params": {"w": jnp.zeros((8, 4))}}   # missing leaves
    with pytest.raises(ValueError, match="structure mismatch"):
        cm.restore(bad)


def test_truncated_checkpoint_detected_and_previous_loaded(tmp_path):
    """A snapshot truncated mid-write (SIGKILL during save) fails its
    checksum; restore() transparently falls back to the previous one."""
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, _state(1), extra={"epoch": 1})
    cm.save(2, _state(2), extra={"epoch": 2})
    leaves = os.path.join(tmp_path, "step_2", "leaves.npz")
    payload = open(leaves, "rb").read()
    with open(leaves, "wb") as f:
        f.write(payload[: len(payload) // 2])          # torn write
    assert not cm.validate(2) and cm.validate(1)
    assert cm.latest_valid_step() == 1
    step, restored, extra = cm.restore(
        jax.tree.map(jnp.zeros_like, _state()))
    assert step == 1 and extra["epoch"] == 1
    want = _state(1)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # asking for the torn snapshot explicitly is an error, not garbage data
    with pytest.raises(CheckpointCorruptError):
        cm.restore(jax.tree.map(jnp.zeros_like, _state()), step=2)


def test_bitflip_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state())
    leaves = os.path.join(tmp_path, "step_1", "leaves.npz")
    payload = bytearray(open(leaves, "rb").read())
    payload[len(payload) // 2] ^= 0xFF
    open(leaves, "wb").write(bytes(payload))
    assert not cm.validate(1)
    with pytest.raises(FileNotFoundError, match="no valid checkpoints"):
        cm.restore(jax.tree.map(jnp.zeros_like, _state()))


def test_numpy_restore_preserves_wide_dtypes(tmp_path):
    """to_device=False must keep int64/float64 exactly (jnp would narrow)."""
    cm = CheckpointManager(str(tmp_path))
    state = {"pop": np.arange(12, dtype=np.int64).reshape(3, 4),
             "F": np.linspace(0, 1, 6, dtype=np.float64).reshape(3, 2)}
    cm.save(1, state)
    _, restored, _ = cm.restore({"pop": np.zeros((3, 4), np.int64),
                                 "F": np.zeros((3, 2), np.float64)},
                                to_device=False)
    assert restored["pop"].dtype == np.int64
    assert restored["F"].dtype == np.float64
    np.testing.assert_array_equal(restored["pop"], state["pop"])
    np.testing.assert_array_equal(restored["F"], state["F"])


def test_elastic_restore_to_mesh(tmp_path):
    """Restore re-device_puts with the current (1-device) mesh sharding —
    the same code path reshards onto any topology."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh_compat
    cm = CheckpointManager(str(tmp_path))
    state = _state()
    cm.save(3, state)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    specs = {"params": {"w": P("data", "model"), "b": P(None)},
             "opt": {"mu": P("data", None), "step": P()}}
    step, restored, _ = cm.restore(jax.tree.map(jnp.zeros_like, state),
                                   mesh=mesh, specs=specs)
    assert step == 3
    w = restored["params"]["w"]
    assert hasattr(w, "sharding")
    np.testing.assert_array_equal(np.asarray(w), np.asarray(state["params"]["w"]))
