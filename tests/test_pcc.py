"""Phase-2: distance metric D, eps_mde/eps_wcde, Pareto analysis."""
import numpy as np

from repro.core.circuits import popcount_netlist, truncated_popcount_netlist
from repro.core.pcc import (PCCEntry, build_pcc_library, evaluate_pcc_pair,
                            pc_pareto, _pareto_front)


def test_exact_pair_zero_distance():
    mde, wcde, cf = evaluate_pcc_pair(popcount_netlist(5), popcount_netlist(4),
                                      5, 4, n_samples=20000)
    assert mde == 0.0 and wcde == 0.0 and cf == 1.0


def test_truncated_pair_nonzero_but_bounded():
    pos = truncated_popcount_netlist(8, 4)
    mde, wcde, cf = evaluate_pcc_pair(pos, popcount_netlist(8), 8, 8,
                                      n_samples=30000)
    assert 0 < mde < 2.0         # the paper's mde values are fractions of 1
    assert wcde <= 8
    assert 0.5 < cf < 1.0


def test_pareto_front_invariants():
    pts = [(0.0, 10.0, 0), (0.1, 9.0, 1), (0.1, 11.0, 2), (0.5, 2.0, 3),
           (0.6, 2.5, 4)]
    front = _pareto_front(pts)
    # no member dominated by another member
    for i in front:
        for j in front:
            if i != j:
                assert not (pts[j][0] <= pts[i][0] and pts[j][1] <= pts[i][1]
                            and (pts[j][0] < pts[i][0] or pts[j][1] < pts[i][1]))
    assert 2 not in front and 4 not in front


def test_build_pcc_library_has_exact_head():
    pc_libs = {4: [popcount_netlist(4), truncated_popcount_netlist(4, 2)],
               3: [popcount_netlist(3)]}
    lib = build_pcc_library([(4, 3)], pc_libs, n_samples=20000)
    entries = lib.get(4, 3)
    assert entries[0].mde == 0.0                   # exact combination first
    assert all(e.mde <= e2.mde for e, e2 in zip(entries, entries[1:]))
    areas = [e.est_area for e in entries]
    assert all(a1 > a2 for a1, a2 in zip(areas, areas[1:]))  # strict Pareto


def test_synth_area_includes_comparator():
    e = build_pcc_library([(5, 5)], {5: [popcount_netlist(5)]},
                          n_samples=1000).get(5, 5)[0]
    assert e.synth_area > e.est_area               # Fig. 6 underestimation
