"""Per-kernel shape/dtype sweeps: interpret-mode Pallas vs jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ternary import pack_ternary
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("M,K,N", [(128, 512, 128), (256, 512, 256),
                                   (128, 1024, 384), (384, 2048, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ternary_matmul_sweep(M, K, N, dtype):
    x = jnp.asarray(RNG.normal(0, 1, (M, K)), dtype)
    codes = jnp.asarray(RNG.integers(-1, 2, (K, N)), jnp.int8)
    w2 = pack_ternary(codes)
    scale = jnp.asarray(np.abs(RNG.normal(1, 0.1, (1, N))), jnp.float32)
    got = ops.ternary_matmul(x, w2, scale, use_kernel=True, interpret=True)
    want = ref.ternary_matmul_ref(x, w2, scale)
    if dtype == jnp.bfloat16:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)
    else:
        # Kernel and reference accumulate the K axis in different block
        # orders, so a flat rtol fails at large K on near-cancelling rows.
        # Bound both against the float64 ground truth by the f32 dot-product
        # rounding envelope ~ eps * sqrt(K) * sum_k |x_k w_k| (per output).
        from repro.core.ternary import unpack_ternary
        x64 = np.asarray(x, np.float64)
        w64 = np.asarray(unpack_ternary(w2, dtype=jnp.float32), np.float64)
        s64 = np.asarray(scale, np.float64)
        exact = (x64 @ w64) * s64
        envelope = (np.abs(x64) @ np.abs(w64)) * np.abs(s64)
        bound = np.finfo(np.float32).eps * np.sqrt(K) * envelope + 1e-6
        assert (np.abs(np.asarray(got, np.float64) - exact) <= bound).all()
        assert (np.abs(np.asarray(want, np.float64) - exact) <= bound).all()


def test_ternary_matmul_exactness_vs_unpacked():
    """Kernel semantics == dense matmul over the unpacked codes."""
    M, K, N = 128, 512, 128
    x = jnp.asarray(RNG.normal(0, 1, (M, K)), jnp.float32)
    codes = jnp.asarray(RNG.integers(-1, 2, (K, N)), jnp.int8)
    w2 = pack_ternary(codes)
    scale = jnp.ones((1, N), jnp.float32)
    got = ops.ternary_matmul(x, w2, scale, use_kernel=True, interpret=True)
    want = x @ codes.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,W", [(256, 1), (256, 8), (512, 17), (1024, 3)])
def test_packed_popcount_sweep(B, W):
    words = jnp.asarray(
        RNG.integers(0, 2**32, (B, W), dtype=np.uint64).astype(np.uint32))
    got = ops.packed_popcount(words, use_kernel=True, interpret=True)
    want = ref.packed_popcount_ref(words)
    bits = np.unpackbits(
        np.asarray(words).view(np.uint8).reshape(B, -1), axis=1).sum(axis=1)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(want) == bits).all()


def test_popcount_edge_values():
    words = jnp.asarray(np.array([[0, 0xFFFFFFFF, 1, 0x80000000]],
                                 dtype=np.uint32))
    got = ops.packed_popcount(words, use_kernel=True, interpret=True)
    assert int(got[0]) == 0 + 32 + 1 + 1
