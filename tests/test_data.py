"""Data substrates: determinism, resumability, dataset shape contracts."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.tabular import DATASETS, make_dataset
from repro.data.tokens import TokenPipeline, TokenPipelineConfig


def test_tabular_dims_match_paper():
    for name, spec in DATASETS.items():
        ds = make_dataset(name)
        assert ds.x_train.shape[1] == spec.n_features
        assert ds.y_train.max() < spec.n_classes
        assert 0.0 <= ds.x_train.min() and ds.x_train.max() <= 1.0
        # 70/30 split (paper Sec. 5)
        frac = len(ds.x_train) / (len(ds.x_train) + len(ds.x_test))
        assert abs(frac - 0.7) < 0.01


def test_tabular_deterministic_across_calls():
    a = make_dataset("cardio", seed=1)
    b = make_dataset("cardio", seed=1)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    c = make_dataset("cardio", seed=2)
    assert not np.array_equal(a.x_train, c.x_train)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_token_pipeline_stateless_resume(step):
    """batch(step) is a pure function — the fault-tolerance contract."""
    cfg = TokenPipelineConfig(vocab=256, seq_len=16, global_batch=4, seed=9)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch_at(step), p2.batch_at(step)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    np.testing.assert_array_equal(np.asarray(b1["labels"]),
                                  np.asarray(b2["labels"]))


def test_token_labels_are_shifted_tokens():
    cfg = TokenPipelineConfig(vocab=128, seq_len=8, global_batch=2, seed=0)
    b = TokenPipeline(cfg).batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_host_batch_slices_global():
    cfg = TokenPipelineConfig(vocab=128, seq_len=8, global_batch=8, seed=0)
    p = TokenPipeline(cfg)
    full = p.batch_at(3)
    h1 = p.host_batch_at(3, 1, 4)
    np.testing.assert_array_equal(np.asarray(h1["tokens"]),
                                  np.asarray(full["tokens"][2:4]))
