"""Wire-transport tier: protocol framing, socket serving, admission, CLI.

Four layers of pinning:

  * **protocol** — the length-prefixed codec round-trips every message
    type through `FrameReader` under arbitrary chunk boundaries
    (hypothesis drives the chunking), and rejects garbage loudly.
  * **bit-identity over the wire** — a fleet of all five golden Table-2
    classifiers served through the asyncio socket server returns labels
    bit-identical to the offline `CircuitProgram.predict` of the very
    bundles in the emit dir (the PR's acceptance criterion).
  * **admission control** — under synthetic overload (engines slowed to a
    crawl, tiny queue limit, full-speed producer) the shed rate is
    nonzero while *accepted* requests keep meeting their SLO: zero
    `n_slo_miss`, every accepted label correct.
  * **CLI contract** — `python -m repro.serve replay` exits nonzero on
    any bit-identity mismatch *without* `--strict` (strict only adds SLO
    + shed gating), pinned against a fabricated mismatch report.
"""
import os
import time

import numpy as np
import pytest

from repro.compile import CircuitProgram, load_program, lower_classifier
from repro.compile.verilog import write_artifacts
from repro.core import tnn as T
from repro.serve import ClassifierFleet, TenantSpec
from repro.serve import protocol as P
from repro.serve.client import FleetClient, FleetShedError
from repro.serve.server import FleetServer

N_EXAMPLES = int(os.environ.get("REPRO_CONFORMANCE_EXAMPLES", "20"))


# ---------------------------------------------------------------------------
# Protocol: framing + codecs as pure logic
# ---------------------------------------------------------------------------
def test_protocol_round_trips_every_message_type():
    x = np.random.default_rng(0).random(7)
    frames = [
        (P.encode_hello(), P.MSG_HELLO, {}),
        (P.encode_welcome(), P.MSG_WELCOME, {}),
        (P.encode_submit(42, "tnn_cardio", x, 12.5), P.MSG_SUBMIT,
         {"req_id": 42, "tenant": "tnn_cardio", "deadline_ms": 12.5}),
        (P.encode_submit(7, "t", x), P.MSG_SUBMIT,
         {"req_id": 7, "deadline_ms": None}),
        (P.encode_result(9, 3, 1.25), P.MSG_RESULT,
         {"req_id": 9, "label": 3, "latency_ms": 1.25}),
        (P.encode_shed(11, 40.0), P.MSG_SHED,
         {"req_id": 11, "retry_after_ms": 40.0}),
        (P.encode_error(13, "boom"), P.MSG_ERROR,
         {"req_id": 13, "message": "boom"}),
        (P.encode_list(), P.MSG_LIST, {}),
        (P.encode_tenants([{"name": "a"}]), P.MSG_TENANTS,
         {"doc": [{"name": "a"}]}),
        (P.encode_stats(), P.MSG_STATS, {}),
        (P.encode_stats_reply({"n": 1}), P.MSG_STATS_REPLY,
         {"doc": {"n": 1}}),
        (P.encode_reload(), P.MSG_RELOAD, {}),
        (P.encode_reloaded({"added": []}), P.MSG_RELOADED,
         {"doc": {"added": []}}),
    ]
    reader = P.FrameReader()
    payloads = reader.feed(b"".join(f for f, _, _ in frames))
    assert len(payloads) == len(frames)
    assert reader.buffered == 0
    for payload, (_, mtype, want) in zip(payloads, frames):
        msg = P.decode_message(payload)
        assert msg.type == mtype
        for key, val in want.items():
            assert getattr(msg, key) == val
    # the submit body carries the float64 readings bit-exactly
    sub = P.decode_message(payloads[2])
    np.testing.assert_array_equal(sub.readings, x)


def test_protocol_rejects_garbage():
    with pytest.raises(P.ProtocolError):
        P.decode_message(b"")                          # empty payload
    with pytest.raises(P.ProtocolError):
        P.decode_message(bytes([P.MSG_SUBMIT]) + b"\x00")   # truncated
    with pytest.raises(P.ProtocolError):
        P.decode_message(bytes([99]))                  # unknown type
    with pytest.raises(P.ProtocolError):               # wrong magic
        P.decode_message(bytes([P.MSG_HELLO]) + b"NOPE\x01")
    with pytest.raises(P.ProtocolError):               # version skew
        P.decode_message(bytes([P.MSG_HELLO]) + P.PROTOCOL_MAGIC
                         + bytes([P.PROTOCOL_VERSION + 1]))
    reader = P.FrameReader(max_frame=16)
    with pytest.raises(P.ProtocolError):               # hostile length prefix
        reader.feed(b"\xff\xff\xff\xff")


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2**64 - 1),
                              st.integers(0, 2**31 - 1),
                              st.floats(0, 1e6, allow_nan=False)),
                    max_size=24),
           st.randoms(use_true_random=False))
    def test_frame_reader_survives_arbitrary_chunking(results, rnd):
        """A stream of RESULT frames split at random byte boundaries
        reassembles to exactly the original messages, in order."""
        stream = b"".join(P.encode_result(rid, lbl, lat)
                          for rid, lbl, lat in results)
        reader = P.FrameReader()
        out = []
        i = 0
        while i < len(stream):
            j = min(len(stream), i + rnd.randint(1, 7))
            out.extend(reader.feed(stream[i:j]))
            i = j
        assert reader.buffered == 0
        got = [P.decode_message(p) for p in out]
        assert [(m.req_id, m.label, m.latency_ms) for m in got] == \
            [(rid, lbl, lat) for rid, lbl, lat in results]


# ---------------------------------------------------------------------------
# Socket serving: all five golden datasets, bit-identical to offline predict
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def golden_emit_dir(tmp_path_factory):
    """All five golden Table-2 classifiers emitted into one fleet dir."""
    from test_golden import GOLDEN_DIR, golden_classifier
    from repro.data.tabular import DATASETS

    out = tmp_path_factory.mktemp("transport_fleet")
    vectors = {}
    for name in sorted(DATASETS):
        cc, _ = golden_classifier(name)
        write_artifacts(cc, out, base=f"tnn_{name}", dataset=name)
        vectors[f"tnn_{name}"] = np.load(GOLDEN_DIR / f"{name}.npz")["x"]
    return out, vectors


@pytest.fixture(scope="module")
def golden_server(golden_emit_dir):
    emit_dir, vectors = golden_emit_dir
    fleet = ClassifierFleet.from_emit_dir(emit_dir, backends="swar",
                                          max_batch=64, deadline_ms=5_000.0)
    server = FleetServer(fleet)
    host, port = server.start_background()
    yield (host, port), emit_dir, vectors
    server.stop()
    fleet.shutdown(drain=True)


def test_socket_labels_bit_identical_on_all_golden_datasets(golden_server):
    """Acceptance: every golden vector of every Table-2 dataset, served
    through HELLO/SUBMIT/RESULT over TCP, gets the exact label the
    offline `CircuitProgram.predict` of the same emitted bundle gives."""
    (host, port), emit_dir, vectors = golden_server
    from repro.compile.artifact import load_manifest

    rows = {r["name"]: r for r in load_manifest(emit_dir)}
    assert len(rows) == 5
    with FleetClient(host, port) as client:
        served = {r["name"] for r in client.tenants()}
        assert served == set(rows)
        for tenant, x in sorted(vectors.items()):
            got = client.classify(tenant, x, timeout=120.0)
            offline = load_program(emit_dir / rows[tenant]["program"])
            want = offline.predict(x).astype(np.int32)
            np.testing.assert_array_equal(
                got, want, err_msg=f"socket transport != offline predict "
                                   f"({tenant})")


def test_socket_pipelines_interleaved_tenants(golden_server):
    """Many in-flight submits across tenants on one connection resolve to
    the right labels by req_id, whatever order completions arrive in."""
    (host, port), emit_dir, vectors = golden_server
    from repro.compile.artifact import load_manifest

    rows = {r["name"]: r for r in load_manifest(emit_dir)}
    refs = {t: load_program(emit_dir / rows[t]["program"]).predict(x)
            for t, x in vectors.items()}
    with FleetClient(host, port) as client:
        pend = []
        for i in range(max(len(x) for x in vectors.values())):
            for t in sorted(vectors):
                if i < len(vectors[t]):
                    pend.append((t, i, client.submit(t, vectors[t][i])))
        for t, i, p in pend:
            assert p.result(timeout=120.0) == int(refs[t][i]), (t, i)


def test_server_reports_stats_and_errors(golden_server):
    (host, port), _, vectors = golden_server
    with FleetClient(host, port) as client:
        tenant = sorted(vectors)[0]
        client.classify(tenant, vectors[tenant][:8], timeout=60.0)
        s = client.stats()
        assert s["fleet"]["n_requests"] >= 8
        assert tenant in s["tenants"]
        from repro.serve.client import FleetClientError
        with pytest.raises(FleetClientError, match="unknown tenant"):
            client.submit("no_such_tenant", vectors[tenant][0]).result(30.0)
        with pytest.raises(FleetClientError, match="features"):
            client.submit(tenant, np.zeros(1)).result(30.0)


# ---------------------------------------------------------------------------
# Admission control under synthetic overload
# ---------------------------------------------------------------------------
def _toy_classifier(F=9, H=5, Cc=4, seed=7):
    rng = np.random.default_rng(seed)
    w1t = rng.integers(-1, 2, size=(F, H)).astype(np.int8)
    w2t = T.balance_zero_counts(rng.normal(size=(H, Cc)), 1 / 3)
    tnn = T.TrainedTNN(w1t=w1t, w2t=w2t, thresholds=np.full(F, 0.5),
                       train_acc=0.0, test_acc=0.0, name=f"toy{seed}")
    return lower_classifier(tnn, *T.exact_netlists(tnn))


class _SlowProgram:
    """Delegating program wrapper that makes every dispatch cost `delay_s`
    — synthetic overload without timing-sensitive producers."""

    def __init__(self, inner, delay_s: float):
        self._inner = inner
        self._delay_s = delay_s

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def predict(self, x):
        time.sleep(self._delay_s)
        return self._inner.predict(x)


def test_overload_sheds_nonzero_and_accepted_requests_meet_slo():
    """Acceptance: with engines slowed so the offered load far exceeds
    capacity, submissions beyond `max_queue` shed with a positive
    `retry_after_ms` — and every *accepted* request still gets the right
    label within its (generous) deadline: zero SLO misses."""
    cc = _toy_classifier()
    prog = CircuitProgram.from_classifier(cc, backend="np")
    ref = CircuitProgram.from_classifier(cc).predict
    deadline_ms = 20_000.0
    spec = TenantSpec(name="slow", program=prog, backend="np", max_batch=8,
                      deadline_ms=deadline_ms, max_queue=16)
    fleet = ClassifierFleet([spec], warmup=False, autostart=False)
    for rep in fleet._tenant("slow").pool.replicas:
        rep.engine.program = _SlowProgram(rep.engine.program, 0.02)
    fleet.start()
    server = FleetServer(fleet)
    host, port = server.start_background()
    x = np.random.default_rng(3).random((400, 9))
    want = ref(x)
    accepted, sheds = [], 0
    try:
        with FleetClient(host, port) as client:
            pend = [client.submit("slow", row, deadline_ms=deadline_ms)
                    for row in x]
            for i, p in enumerate(pend):
                try:
                    label = p.result(timeout=120.0)
                except FleetShedError as exc:
                    sheds += 1
                    assert exc.retry_after_ms >= 1.0
                else:
                    accepted.append((i, label))
            stats = client.stats()
    finally:
        server.stop()
        fleet.shutdown(drain=True)

    assert sheds > 0, "overload never shed — admission control is inert"
    assert len(accepted) + sheds == x.shape[0]
    assert len(accepted) > 0
    for i, label in accepted:            # every accepted label is correct
        assert label == int(want[i]), i
    tstats = stats["tenants"]["slow"]
    assert stats["fleet"]["n_shed"] == tstats["n_shed"] == sheds
    assert tstats["n_slo_miss"] == 0, \
        "accepted requests missed SLO under overload — shedding too late"
    assert stats["fleet"]["n_slo_miss"] == 0


def test_shed_recovers_once_backlog_drains():
    """After an overload burst is served, the same tenant accepts again —
    shedding is a queue-depth signal, not a latched state."""
    cc = _toy_classifier(seed=11)
    prog = CircuitProgram.from_classifier(cc, backend="np")
    spec = TenantSpec(name="t", program=prog, backend="np", max_batch=4,
                      deadline_ms=60_000.0, max_queue=8)
    fleet = ClassifierFleet([spec], warmup=False, autostart=False)
    for rep in fleet._tenant("t").pool.replicas:
        rep.engine.program = _SlowProgram(rep.engine.program, 0.01)
    fleet.start()
    from repro.serve import FleetOverloadError

    x = np.random.default_rng(5).random((64, 9))
    try:
        shed = 0
        for row in x:
            try:
                fleet.submit("t", row)
            except FleetOverloadError:
                shed += 1
        assert shed > 0
        fleet.flush(timeout=60.0)
        # queue drained: accepted again (short budget so it ships promptly)
        req = fleet.submit("t", x[0], deadline_ms=200.0)
        assert req.result(timeout=30.0) is not None
    finally:
        fleet.shutdown(drain=True)


# ---------------------------------------------------------------------------
# Hot reload over the wire: RELOAD RPC + the manifest mtime watcher
# ---------------------------------------------------------------------------
def test_server_hot_reload_rpc_and_watcher(tmp_path):
    write_artifacts(_toy_classifier(seed=7), tmp_path, base="alpha")
    fleet = ClassifierFleet.from_emit_dir(tmp_path, backends="swar",
                                          max_batch=32, deadline_ms=500.0)
    server = FleetServer(fleet, watch_manifest=True, watch_interval_s=0.05)
    host, port = server.start_background()
    try:
        with FleetClient(host, port) as client:
            assert [t["name"] for t in client.tenants()] == ["alpha"]
            # explicit RELOAD round-trip picks up a new tenant (the mtime
            # watcher may legitimately win the race and sync it first, in
            # which case the RPC reconcile is a no-op — either way the
            # tenant must be live afterwards)
            cc_beta = _toy_classifier(F=6, H=4, Cc=3, seed=11)
            write_artifacts(cc_beta, tmp_path, base="beta")
            actions = client.reload()
            assert actions["added"] in ([], ["beta"])
            assert "beta" in {t["name"] for t in client.tenants()}
            x = np.random.default_rng(0).random((16, 6))
            np.testing.assert_array_equal(
                client.classify("beta", x, timeout=60.0),
                CircuitProgram.from_classifier(cc_beta).predict(x))
            # the mtime watcher catches a re-emit on its own
            gen = [t for t in client.tenants()
                   if t["name"] == "alpha"][0]["generation"]
            write_artifacts(_toy_classifier(seed=42), tmp_path, base="alpha")
            deadline = time.monotonic() + 15.0
            while time.monotonic() < deadline:
                rows = {t["name"]: t for t in client.tenants()}
                if rows["alpha"]["generation"] > gen:
                    break
                time.sleep(0.05)
            else:
                pytest.fail("watcher never hot-reloaded the re-emitted "
                            "tenant")
            labels = client.classify("alpha",
                                     np.random.default_rng(1).random((8, 9)),
                                     timeout=60.0)
            assert labels.shape == (8,)
    finally:
        server.stop()
        fleet.shutdown(drain=True)


# ---------------------------------------------------------------------------
# CLI contract: mismatch exits nonzero even without --strict
# ---------------------------------------------------------------------------
def _fake_report(match: bool, slo_miss: int = 0, shed: int = 0,
                 errors: list | None = None) -> dict:
    return {
        "tenants": {"t": {"backend": "swar", "replicas": 1, "dataset": "d",
                          "readings": 4, "labels_match_offline": match,
                          "slo_miss": slo_miss, "n_shed": shed,
                          "worst_latency_ms": 1.0, "req_p50_ms": 1.0,
                          "req_p99_ms": 1.0}},
        "fleet": {"n_readings": 4, "n_batches": 1, "n_slo_miss": slo_miss,
                  "n_shed": shed, "req_p99_ms": 1.0},
        "errors": errors or [],
        "labels_match_offline": match,
        "transport": "inproc",
        "producers": 1,
    }


def test_exit_code_mismatch_fails_without_strict():
    from repro.serve.__main__ import exit_code

    assert exit_code(_fake_report(True), strict=False) == 0
    # regression: a bit-identity mismatch must fail even without --strict
    assert exit_code(_fake_report(False), strict=False) == 1
    assert exit_code(_fake_report(False), strict=True) == 1
    # dispatch errors too
    assert exit_code(_fake_report(True, errors=["boom"]), strict=False) == 1
    # SLO misses and sheds gate only under --strict
    assert exit_code(_fake_report(True, slo_miss=3), strict=False) == 0
    assert exit_code(_fake_report(True, slo_miss=3), strict=True) == 1
    assert exit_code(_fake_report(True, shed=2), strict=False) == 0
    assert exit_code(_fake_report(True, shed=2), strict=True) == 1


def test_replay_cli_exits_nonzero_on_mismatch_without_strict(
        golden_emit_dir, monkeypatch):
    """End-to-end regression for the CLI: fabricate a label mismatch in
    the replay path and check `python -m repro.serve replay` (no
    --strict) returns 1."""
    import repro.serve.__main__ as M

    emit_dir, _ = golden_emit_dir
    monkeypatch.setattr(
        M, "replay_fleet",
        lambda fleet, streams, producers=4, timeout=120.0:
            _fake_report(False))
    rc = M.main(["replay", "--emit-dir", str(emit_dir),
                 "--replay", "all", "--readings", "4", "--producers", "1"])
    assert rc == 1
    # and the legacy bare-flag spelling resolves to the same path
    rc = M.main(["--emit-dir", str(emit_dir),
                 "--replay", "all", "--readings", "4", "--producers", "1"])
    assert rc == 1


# ---------------------------------------------------------------------------
# Protocol v2: batch frames, version negotiation, the 64 MiB cap
# ---------------------------------------------------------------------------
def test_protocol_v2_batch_frames_round_trip():
    rng = np.random.default_rng(1)
    x = rng.random((13, 7))
    rids = np.arange(100, 113, dtype=np.uint64)
    dls = np.full(13, np.nan)
    dls[3] = 12.5
    (payload,) = P.FrameReader().feed(
        P.encode_submit_batch(rids, "tnn_cardio", x, dls))
    msg = P.decode_message(payload)
    assert msg.type == P.MSG_SUBMIT_BATCH and msg.tenant == "tnn_cardio"
    np.testing.assert_array_equal(msg.req_ids, rids)
    np.testing.assert_array_equal(msg.readings, x)   # bit-exact plane
    assert np.isnan(msg.deadlines_ms[0]) and msg.deadlines_ms[3] == 12.5

    labels = (np.arange(13) % 4).astype(np.int32)
    lats = np.linspace(0.5, 2.0, 13)
    (payload,) = P.FrameReader().feed(
        P.encode_result_batch(rids, labels, lats))
    msg = P.decode_message(payload)
    assert msg.type == P.MSG_RESULT_BATCH
    np.testing.assert_array_equal(msg.req_ids, rids)
    np.testing.assert_array_equal(msg.labels, labels)
    np.testing.assert_allclose(msg.latencies_ms, lats)


def test_protocol_version_negotiation():
    assert P.negotiate_version(1) == 1      # a v1 client is served at v1
    assert P.negotiate_version(P.PROTOCOL_VERSION) == P.PROTOCOL_VERSION
    assert P.negotiate_version(99) == P.PROTOCOL_VERSION    # future client
    with pytest.raises(P.ProtocolError):
        P.negotiate_version(0)              # below the supported floor
    # HELLO / WELCOME carry the version on the wire
    assert P.decode_message(P.encode_hello(1)[4:]).version == 1
    assert P.decode_message(P.encode_welcome(2)[4:]).version == 2


if _HAVE_HYPOTHESIS:

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 9), st.integers(1, 6),
                              st.integers(0, 2**32)),
                    min_size=1, max_size=6),
           st.randoms(use_true_random=False))
    def test_batch_frames_survive_arbitrary_chunking(shapes, rnd):
        """SUBMIT_BATCH frames split at random byte boundaries reassemble
        to the exact req_id tables and reading planes, in order."""
        frames, want = [], []
        for k, (b, f, seed) in enumerate(shapes):
            x = np.random.default_rng(seed).random((b, f))
            rids = np.arange(k * 1000, k * 1000 + b, dtype=np.uint64)
            frames.append(P.encode_submit_batch(rids, f"t{k}", x))
            want.append((f"t{k}", rids, x))
        stream = b"".join(frames)
        reader = P.FrameReader()
        out, i = [], 0
        while i < len(stream):
            j = min(len(stream), i + rnd.randint(1, 7))
            out.extend(reader.feed(stream[i:j]))
            i = j
        assert reader.buffered == 0 and len(out) == len(frames)
        for payload, (tenant, rids, x) in zip(out, want):
            msg = P.decode_message(payload)
            assert msg.tenant == tenant
            np.testing.assert_array_equal(msg.req_ids, rids)
            np.testing.assert_array_equal(msg.readings, x)


def test_batch_frame_near_the_64mib_cap_decodes():
    """`batch_rows_per_frame` is the exact fit: its row count lands within
    a whisker of MAX_FRAME and still decodes; one hostile byte past the
    cap is rejected at the framer."""
    F = 4096
    rows = P.batch_rows_per_frame(F)
    frame = P.encode_submit_batch(np.arange(rows, dtype=np.uint64), "t",
                                  np.zeros((rows, F)))
    assert len(frame) - 4 <= P.MAX_FRAME
    assert len(frame) - 4 > 0.95 * P.MAX_FRAME      # actually near the cap
    (payload,) = P.FrameReader().feed(frame)
    msg = P.decode_message(payload)
    assert msg.readings.shape == (rows, F)
    import struct as _struct

    with pytest.raises(P.ProtocolError):
        P.FrameReader().feed(_struct.pack("!I", P.MAX_FRAME + 1))


def test_oversized_batch_gets_clean_error_not_a_hung_connection(
        golden_server):
    """A frame bigger than the cap draws a connection-level ERROR and a
    close — never a silent hang."""
    import socket as _socket
    import struct as _struct

    (host, port), _, _ = golden_server

    def read_frame(s):
        head = b""
        while len(head) < 4:
            head += s.recv(4 - len(head))
        (ln,) = _struct.unpack("!I", head)
        buf = b""
        while len(buf) < ln:
            buf += s.recv(ln - len(buf))
        return buf

    with _socket.create_connection((host, port), timeout=30) as s:
        s.sendall(P.encode_hello())
        assert P.decode_message(read_frame(s)).type == P.MSG_WELCOME
        s.sendall(_struct.pack("!I", P.MAX_FRAME + 1))  # hostile batch size
        msg = P.decode_message(read_frame(s))
        assert msg.type == P.MSG_ERROR and msg.req_id == P.CONN_ERR
        assert s.recv(1) == b""             # and the server hung up


def test_v1_client_against_v2_server_stays_bit_identical(golden_server):
    """Version negotiation: a client pinned to protocol v1 is served at
    v1 (per-reading SUBMIT frames) and still gets offline-exact labels."""
    (host, port), emit_dir, vectors = golden_server
    from repro.compile.artifact import load_manifest

    tenant = sorted(vectors)[0]
    x = vectors[tenant]
    rows = {r["name"]: r for r in load_manifest(emit_dir)}
    want = load_program(
        emit_dir / rows[tenant]["program"]).predict(x).astype(np.int32)
    with FleetClient(host, port, protocol_version=1) as client:
        assert client.protocol_version == 1
        np.testing.assert_array_equal(
            client.classify(tenant, x, timeout=120.0), want)


def test_submit_many_chunks_batch_frames_bit_identical(golden_server):
    """The v2 batch path, forced through many small SUBMIT_BATCH frames
    (tiny max_frame), resolves every row to the offline label."""
    (host, port), emit_dir, vectors = golden_server
    from repro.compile.artifact import load_manifest

    tenant = sorted(vectors)[0]
    x = vectors[tenant]
    rows = {r["name"]: r for r in load_manifest(emit_dir)}
    want = load_program(
        emit_dir / rows[tenant]["program"]).predict(x).astype(np.int32)
    with FleetClient(host, port) as client:
        assert client.protocol_version == P.PROTOCOL_VERSION
        handles = client.submit_many(tenant, x, max_frame=1 << 12)
        got = np.array([h.result(120.0) for h in handles], dtype=np.int32)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Batched ingest: fleet fast path, sharded accept loops, UDP, coalescer
# ---------------------------------------------------------------------------
def test_fleet_submit_many_partial_admission_and_identity():
    """One lock acquisition admits the head of the frame up to queue room
    and sheds the tail with a retry hint; admitted rows serve to
    offline-exact labels in arrival order."""
    cc = _toy_classifier()
    prog = CircuitProgram.from_classifier(cc, backend="np")
    ref = CircuitProgram.from_classifier(cc).predict
    spec = TenantSpec(name="t", program=prog, backend="np", max_batch=8,
                      deadline_ms=20_000.0, max_queue=16)
    fleet = ClassifierFleet([spec], warmup=False, autostart=False)
    for rep in fleet._tenant("t").pool.replicas:
        rep.engine.program = _SlowProgram(rep.engine.program, 0.01)
    fleet.start()
    x = np.random.default_rng(5).random((64, 9))
    want = ref(x)
    try:
        reqs, shed_idx, retry_ms = fleet.submit_many("t", x)
        assert len(reqs) + len(shed_idx) == 64
        assert len(shed_idx) >= 64 - 16 > 0 and retry_ms > 0
        # admission is in arrival order: the shed rows are the tail
        np.testing.assert_array_equal(
            shed_idx, np.arange(64 - len(shed_idx), 64))
        for r in reqs:
            r.result(60.0)
        labels = np.array([r.label for r in reqs], dtype=np.int32)
        np.testing.assert_array_equal(labels, want[:len(reqs)])
    finally:
        fleet.shutdown(drain=True)


def test_sharded_server_udp_ingest_and_coalescer():
    """The swarm transports in one sweep: SO_REUSEPORT shards serve
    concurrent connections correctly, the client-side coalescer flushes
    on both size and age, and fire-and-forget UDP datagrams land in the
    server's ingest counters."""
    from repro.serve.client import CoalescingSubmitter, UdpSwarmSender
    from repro.serve.server import FleetServer as _FS

    cc = _toy_classifier()
    prog = CircuitProgram.from_classifier(cc, backend="np")
    ref = CircuitProgram.from_classifier(cc).predict
    spec = TenantSpec(name="t", program=prog, backend="np", max_batch=32,
                      deadline_ms=10_000.0)
    fleet = ClassifierFleet([spec], warmup=False, autostart=False)
    fleet.start()
    server = _FS(fleet, shards=2, udp_port=0)
    host, port = server.start_background()
    x = np.random.default_rng(11).random((96, 9))
    want = ref(x).astype(np.int32)
    try:
        with FleetClient(host, port) as c, FleetClient(host, port) as c2:
            np.testing.assert_array_equal(
                c2.classify("t", x[:32], timeout=60.0), want[:32])
            with CoalescingSubmitter(c, max_rows=16,
                                     max_delay_ms=25.0) as cs:
                pends = [cs.submit("t", x[i]) for i in range(40)]
                got = np.array([p.result(60.0) for p in pends],
                               dtype=np.int32)
            np.testing.assert_array_equal(got, want[:40])

            before = c.stats()["transport"]["udp"]["n_readings"]
            with UdpSwarmSender(host, server.udp_address[1]) as u:
                n = u.send_many("t", x)
                u.send("t", x[0])
            deadline = time.monotonic() + 30
            got_n = 0
            while time.monotonic() < deadline:
                got_n = c.stats()["transport"]["udp"]["n_readings"] - before
                if got_n >= n + 1:
                    break
                time.sleep(0.05)
            assert got_n == n + 1, f"UDP ingest saw {got_n}/{n + 1}"
            assert c.stats()["transport"]["shards"] == 2
    finally:
        server.stop()
        fleet.shutdown(drain=True)
