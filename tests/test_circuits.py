"""Netlist substrate: builders, bit-parallel simulation, cost model."""
import numpy as np
import pytest

from repro.core.circuits import (
    comparator_geq_netlist, compose_pcc, eval_vectors, exhaustive_vectors,
    pc_error, popcount_netlist, popcount_of_packed, popcount_width,
    truncated_popcount_netlist, pack_vectors,
)


@pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 11, 16])
def test_popcount_exact(n):
    nl = popcount_netlist(n)
    packed, true = eval_vectors(n)
    mae, wce = pc_error(nl, packed, true)
    assert mae == 0 and wce == 0


def test_popcount_large_stratified():
    nl = popcount_netlist(47)
    packed, true = eval_vectors(47, n_samples=1 << 13)
    mae, wce = pc_error(nl, packed, true)
    assert mae == 0 and wce == 0


@pytest.mark.parametrize("j", [1, 2, 4, 5])
def test_comparator(j):
    cmp_nl = comparator_geq_netlist(j)
    vecs = exhaustive_vectors(2 * j)
    out = cmp_nl.eval_uint(vecs)
    S = 1 << (2 * j)
    idx = np.arange(S)
    a, b = idx & ((1 << j) - 1), idx >> j
    assert (out[:S] == (a >= b)).all()


@pytest.mark.parametrize("npos,nneg", [(3, 3), (5, 4), (2, 7)])
def test_pcc_semantics(npos, nneg):
    pcc = compose_pcc(popcount_netlist(npos), popcount_netlist(nneg),
                      npos, nneg)
    vecs = exhaustive_vectors(npos + nneg)
    out = pcc.eval_uint(vecs)
    S = 1 << (npos + nneg)
    idx = np.arange(S)
    pos = sum((idx >> k) & 1 for k in range(npos))
    neg = sum((idx >> (npos + k)) & 1 for k in range(nneg))
    assert (out[:S] == (pos >= neg)).all()


def test_truncation_baseline_bounds():
    n, drop = 8, 4
    nl = truncated_popcount_netlist(n, drop)
    packed, true = eval_vectors(n)
    mae, wce = pc_error(nl, packed, true)
    exact = popcount_netlist(n)
    assert nl.area() < exact.area()
    assert wce <= drop                      # at most the dropped bits +- comp
    assert abs(mae - 0.75) < 1e-9           # E|Binom(4,.5) - 2| analytically


def test_pack_vectors_roundtrip():
    r = np.random.default_rng(0)
    vecs = (r.random((100, 9)) < 0.5).astype(np.uint8)
    packed = pack_vectors(vecs)
    assert packed.shape == (9, 2)
    assert (popcount_of_packed(packed)[:100] == vecs.sum(1)).all()


def test_cost_model_anchors():
    """EGFET anchors: exact TNN-ish circuits land in the paper's magnitude."""
    # a breast-cancer-scale hidden neuron (5,5) should cost a few mm^2
    pcc = compose_pcc(popcount_netlist(5), popcount_netlist(5), 5, 5)
    c = pcc.cost()
    assert 1.0 < c.area_mm2 < 15.0
    assert 0.001 < c.power_mw < 0.1
