"""NSGA-II machinery + hypothesis property tests on its invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.nsga2 import (NSGA2Config, crowding_distance,
                              fast_non_dominated_sort, nsga2)


def test_non_dominated_sort_simple():
    F = np.array([[0.0, 1.0], [1.0, 0.0], [0.5, 0.5], [1.0, 1.0], [2.0, 2.0]])
    fronts = fast_non_dominated_sort(F)
    assert set(fronts[0].tolist()) == {0, 1, 2}
    assert set(fronts[1].tolist()) == {3}
    assert set(fronts[2].tolist()) == {4}


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0, 10)),
                min_size=3, max_size=30))
def test_sort_front0_is_truly_nondominated(points):
    F = np.array(points)
    fronts = fast_non_dominated_sort(F)
    f0 = fronts[0]
    for i in f0:
        for j in range(F.shape[0]):
            dominates = ((F[j] <= F[i]).all() and (F[j] < F[i]).any())
            assert not dominates
    # every index appears exactly once across fronts
    allidx = np.concatenate(fronts)
    assert sorted(allidx.tolist()) == list(range(F.shape[0]))


def test_crowding_boundary_infinite():
    F = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    cd = crowding_distance(F)
    assert np.isinf(cd[0]) and np.isinf(cd[3])
    assert np.isfinite(cd[1]) and np.isfinite(cd[2])


def test_nsga2_finds_known_front():
    """Objective: f0 = sum(x)/n, f1 = sum(domain-1-x)/n — the Pareto front
    is the full diagonal; check convergence toward low f0+f1 corners."""
    n_genes, dom = 8, 5
    domains = np.full(n_genes, dom)

    def objective(pop):
        f0 = pop.sum(1) / (n_genes * (dom - 1))
        f1 = (dom - 1 - pop).sum(1) / (n_genes * (dom - 1))
        # add a "cost" making middle values dominated
        pen = ((pop == 2).sum(1)) * 0.2
        return np.stack([f0 + pen, f1 + pen], 1)

    res = nsga2(domains, objective, NSGA2Config(pop_size=24, n_generations=60,
                                                seed=0))
    assert res.pareto_f.shape[1] == 2
    # extremes should be (near) discovered, and the front well-populated
    assert res.pareto_f[:, 0].min() <= 0.3
    assert res.pareto_f[:, 1].min() <= 0.3
    assert len(res.pareto_f) >= 5
    # front sorted by obj0 must be decreasing in obj1 (Pareto)
    f = res.pareto_f
    assert all(f[i + 1, 1] <= f[i, 1] + 1e-12 for i in range(len(f) - 1))
    # history improves
    assert res.history[-1][1] <= res.history[0][1] + 1e-12


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4), st.integers(0, 1000))
def test_nsga2_respects_domains(n_genes, dom, seed):
    domains = np.full(n_genes, dom)

    def objective(pop):
        assert (pop >= 0).all() and (pop < dom).all()
        return np.stack([pop.sum(1).astype(float),
                         (dom - 1 - pop).sum(1).astype(float)], 1)

    res = nsga2(domains, objective,
                NSGA2Config(pop_size=8, n_generations=5, seed=seed))
    assert (res.pareto_x >= 0).all() and (res.pareto_x < dom).all()
