"""Sensor-stream serving engine: label correctness across padded batch
shapes, request-queue bookkeeping, and stats sanity."""
import numpy as np
import pytest

from repro.core import tnn as T
from repro.compile import CircuitProgram, lower_classifier
from repro.serve.engine import CircuitServingEngine


@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(7)
    w1t = rng.integers(-1, 2, size=(9, 5)).astype(np.int8)
    w2t = T.balance_zero_counts(rng.normal(size=(5, 4)), 1 / 3)
    tnn = T.TrainedTNN(w1t=w1t, w2t=w2t, thresholds=np.full(9, 0.5),
                       train_acc=0.0, test_acc=0.0, name="toy")
    cc = lower_classifier(tnn, *T.exact_netlists(tnn))
    return tnn, cc, CircuitProgram.from_classifier(cc)


@pytest.mark.parametrize("n,max_batch", [(1, 32), (7, 32), (130, 32),
                                         (64, 64), (5, 1)])
def test_stream_labels_match_direct_predict(toy, n, max_batch):
    """Padding to the fixed jit shape must never leak into the labels."""
    _, _, prog = toy
    engine = CircuitServingEngine(prog, max_batch=max_batch)
    engine.warmup()
    rng = np.random.default_rng(n * 100 + max_batch)
    x = rng.random((n, 9))
    labels = engine.classify_stream(x)
    assert labels.shape == (n,)
    assert (labels == prog.predict(x)).all()
    assert engine.stats.n_readings == n
    assert engine.stats.n_batches == -(-n // max_batch)


def test_submit_flush_queue(toy):
    _, _, prog = toy
    engine = CircuitServingEngine(prog, max_batch=8)
    engine.warmup()
    rng = np.random.default_rng(0)
    x = rng.random((21, 9))
    reqs = [engine.submit(row) for row in x]
    assert engine.pending == 21
    assert [r.uid for r in reqs] == list(range(21))
    done = engine.flush()
    assert engine.pending == 0
    assert [r.uid for r in done] == list(range(21))     # arrival order
    ref = prog.predict(x)
    for r in done:
        assert r.label == int(ref[r.uid])
        assert r.latency_ms is not None and r.latency_ms >= 0.0


def test_stats_summary(toy):
    _, _, prog = toy
    engine = CircuitServingEngine(prog, max_batch=16)
    engine.warmup()
    engine.classify_stream(np.random.default_rng(1).random((100, 9)))
    s = engine.stats.summary()
    assert s["n_readings"] == 100
    assert s["n_batches"] == 7
    assert s["readings_per_s"] > 0
    assert s["p50_ms"] <= s["p99_ms"]
    assert s["busy_s"] > 0


def test_stats_rings_stay_bounded_on_long_streams(toy):
    """Regression: ServeStats.batch_ms grew one entry per dispatch forever;
    a day-long sensor stream must hold stats memory constant."""
    _, _, prog = toy
    engine = CircuitServingEngine(prog, max_batch=1, stats_window=64)
    engine.warmup()
    engine.classify_stream(np.random.default_rng(2).random((300, 9)))
    s = engine.stats
    assert s.n_batches == 300                       # exact totals survive
    assert s.n_readings == 300
    assert len(s.batch_ms) == 64                    # ring, not a list
    assert len(s.batch_ms.values()) == 64
    assert s.batch_ms.total_pushed == 300
    assert s.percentile_ms(50) <= s.percentile_ms(99)
    # request ring bounds identically
    for _ in range(200):
        s.record_request(1.0, deadline_ms=2.0)
    assert len(s.request_ms) == 64
    assert s.n_requests == 200 and s.n_slo_miss == 0
    s.record_request(3.0, deadline_ms=2.0)
    assert s.n_slo_miss == 1


def test_concurrent_submit_flush_every_latency_set(toy):
    """Regression: requests arriving while a dispatch was in flight (or a
    second flusher racing the queue) could complete without latency_ms.
    Under concurrent submit + double flush, every request must be answered
    exactly once with label and latency both set."""
    import threading

    _, _, prog = toy
    engine = CircuitServingEngine(prog, max_batch=4)
    engine.warmup()
    rng = np.random.default_rng(3)
    x = rng.random((120, 9))
    reqs: list = []
    done_lists: list[list] = [[], []]
    stop = threading.Event()

    def producer():
        for row in x:
            reqs.append(engine.submit(row))
            if len(reqs) % 10 == 0:
                import time
                time.sleep(0.0005)
        stop.set()

    def flusher(k: int):
        while not stop.is_set() or engine.pending:
            done_lists[k].extend(engine.flush())

    threads = [threading.Thread(target=producer)] + [
        threading.Thread(target=flusher, args=(k,)) for k in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)

    assert engine.pending == 0
    served = done_lists[0] + done_lists[1]
    assert sorted(r.uid for r in served) == list(range(120))  # exactly once
    ref = prog.predict(x)
    for r in reqs:
        assert r.label == int(ref[r.uid])
        assert r.latency_ms is not None and r.latency_ms >= 0.0
    assert engine.stats.n_requests == 120


def test_engine_input_validation(toy):
    _, cc, prog = toy
    engine = CircuitServingEngine(prog, max_batch=4)
    with pytest.raises(ValueError):
        engine.submit(np.zeros(5))           # wrong feature count
    with pytest.raises(ValueError):
        engine.classify_stream(np.zeros((3, 5)))
    with pytest.raises(ValueError):
        CircuitServingEngine(prog, max_batch=0)
    from repro.compile import lower_netlist
    from repro.core.circuits import popcount_netlist
    bare = CircuitProgram.from_netlist(popcount_netlist(4))
    with pytest.raises(ValueError):          # not a classifier program
        CircuitServingEngine(bare)
