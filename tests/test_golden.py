"""Golden-vector regression: pinned compiler output per Table-2 dataset.

For every dataset a small fixture under tests/golden/ pins, for one
deterministically constructed classifier, the `repro.compile` contract:
end-to-end predictions of the compiled `CircuitProgram` on committed raw
sensor readings, and the full EGFET report (gate counts, histogram, logic
depth, area/power, power-source verdict).  Any silent drift in the lowering
pipeline — builder composition, DCE, levelization, argmax semantics, cost
tables — breaks an exact comparison here.

The golden classifier is built without training: ternary weights come from
a seeded numpy stream (sign/magnitude threshold), output columns are
zero-balanced with the production `balance_zero_counts`, thresholds are the
ABC medians.  Everything is integer or platform-stable float64/float32
arithmetic, so fixtures generated on one x86 host verify on another.

Regenerate (after an *intentional* compiler change) with:

    PYTHONPATH=src python tests/test_golden.py --regen
"""
import hashlib
import json
from pathlib import Path

import numpy as np
import pytest

from repro.compile import CircuitProgram, egfet_report, lower_classifier
from repro.core.tnn import TrainedTNN, balance_zero_counts, exact_netlists
from repro.core.ternary import TERNARY_THRESHOLD, abc_fit_thresholds
from repro.data.tabular import DATASETS, make_dataset

GOLDEN_DIR = Path(__file__).parent / "golden"
N_VECTORS = 96


def golden_classifier(name: str):
    """Deterministic (untrained) classifier + raw eval vectors for `name`."""
    ds = make_dataset(name)
    spec = ds.spec
    F, H, Cc = spec.topology
    digest = hashlib.sha256(f"golden:{name}".encode()).digest()
    rng = np.random.default_rng(int.from_bytes(digest[:8], "little"))
    w1_latent = rng.normal(0.0, 0.7, size=(F, H))
    w2_latent = rng.normal(0.0, 0.7, size=(H, Cc))
    w1t = (np.sign(w1_latent)
           * (np.abs(w1_latent) > TERNARY_THRESHOLD)).astype(np.int8)
    w2t = balance_zero_counts(w2_latent, TERNARY_THRESHOLD)
    tnn = TrainedTNN(w1t=w1t, w2t=w2t,
                     thresholds=abc_fit_thresholds(ds.x_train),
                     train_acc=0.0, test_acc=0.0, name=name)
    cc = lower_classifier(tnn, *exact_netlists(tnn))
    x = ds.x_test[:N_VECTORS].astype(np.float32)
    return cc, x


def compute_golden(name: str) -> tuple[np.ndarray, np.ndarray, dict]:
    cc, x = golden_classifier(name)
    labels = CircuitProgram.from_classifier(cc).predict(x)
    return x, labels, egfet_report(cc)


@pytest.mark.parametrize("name", sorted(DATASETS))
def test_compile_matches_golden(name):
    npz_path = GOLDEN_DIR / f"{name}.npz"
    report_path = GOLDEN_DIR / f"{name}_report.json"
    assert npz_path.exists() and report_path.exists(), (
        f"golden fixtures for {name!r} missing — run "
        "`PYTHONPATH=src python tests/test_golden.py --regen`")
    fix = np.load(npz_path)
    want_report = json.loads(report_path.read_text())

    cc, x = golden_classifier(name)
    np.testing.assert_array_equal(
        x, fix["x"], err_msg="golden input vectors drifted (dataset gen?)")
    got_report = egfet_report(cc)
    drift = {k: (want_report.get(k), got_report.get(k))
             for k in set(want_report) | set(got_report)
             if want_report.get(k) != got_report.get(k)}
    assert got_report == want_report, f"EGFET report drift for {name}: {drift}"
    program = CircuitProgram.from_classifier(cc)
    np.testing.assert_array_equal(program.predict(fix["x"]), fix["labels"],
                                  err_msg=f"compiled predictions drift "
                                          f"({name})")
    # np backend must pin to the same goldens (cross-backend safety net)
    program_np = CircuitProgram.from_classifier(cc, backend="np")
    np.testing.assert_array_equal(program_np.predict(fix["x"]),
                                  fix["labels"])


def regenerate() -> None:
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name in sorted(DATASETS):
        x, labels, report = compute_golden(name)
        np.savez_compressed(GOLDEN_DIR / f"{name}.npz", x=x, labels=labels)
        (GOLDEN_DIR / f"{name}_report.json").write_text(
            json.dumps(report, indent=2, sort_keys=True) + "\n")
        print(f"{name}: {report['n_gates']} gates, depth "
              f"{report['logic_depth']}, labels {labels[:8].tolist()}...")


if __name__ == "__main__":
    import sys
    if "--regen" not in sys.argv:
        raise SystemExit("usage: python tests/test_golden.py --regen")
    regenerate()
