"""Concurrency/soak tier for the multi-tenant serving fleet.

Three layers of pinning:

  * **soak** — N producer threads blast interleaved readings at a
    multi-tenant fleet for a fixed wall-clock budget; every request must be
    answered exactly once, bit-identical to the offline
    `CircuitProgram.predict`, and no request may exceed its deadline by
    more than one dispatch interval (+ CI scheduling slack).
  * **property** — the deadline-driven `MicroBatcher` policy is pure logic
    over an injected clock, so hypothesis drives arbitrary arrival orders,
    batch sizes and budgets through the exact production decision code:
    never reorders within a tenant, never exceeds `max_batch`, drains to
    empty on shutdown.
  * **lifecycle** — manifest round-trips, deadline-triggered partial
    flushes, drain-vs-cancel shutdown, validation errors.

Budget knob: the hypothesis example count follows the repo-wide
REPRO_CONFORMANCE_EXAMPLES (nightly CI raises it).
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.compile import CircuitProgram, lower_classifier, write_artifacts
from repro.compile.artifact import load_manifest
from repro.core import tnn as T
from repro.serve import ClassifierFleet, MicroBatcher, TenantSpec

N_EXAMPLES = int(os.environ.get("REPRO_CONFORMANCE_EXAMPLES", "20"))

# (features, hidden, classes, rng seed) per toy tenant
TOY_TENANTS = {
    "toy_a": (9, 5, 4, 7),
    "toy_b": (6, 4, 3, 11),
    "toy_c": (12, 6, 5, 13),
}


def _toy_classifier(F, H, Cc, seed):
    rng = np.random.default_rng(seed)
    w1t = rng.integers(-1, 2, size=(F, H)).astype(np.int8)
    w2t = T.balance_zero_counts(rng.normal(size=(H, Cc)), 1 / 3)
    tnn = T.TrainedTNN(w1t=w1t, w2t=w2t, thresholds=np.full(F, 0.5),
                       train_acc=0.0, test_acc=0.0, name=f"toy{seed}")
    return lower_classifier(tnn, *T.exact_netlists(tnn))


@pytest.fixture(scope="module")
def emit_dir(tmp_path_factory):
    """An emit directory holding every toy tenant + its manifest."""
    out = tmp_path_factory.mktemp("fleet_artifacts")
    ccs = {}
    for name, (F, H, Cc, seed) in TOY_TENANTS.items():
        cc = _toy_classifier(F, H, Cc, seed)
        write_artifacts(cc, out, base=name)
        ccs[name] = cc
    return out, ccs


def test_manifest_lists_every_tenant(emit_dir):
    out, ccs = emit_dir
    rows = load_manifest(out)
    assert [r["name"] for r in rows] == sorted(TOY_TENANTS)
    for r in rows:
        F = TOY_TENANTS[r["name"]][0]
        assert r["n_features"] == F
        assert (out / r["program"]).exists()


def test_reemit_replaces_manifest_row(emit_dir, tmp_path):
    cc = _toy_classifier(5, 3, 2, 42)
    for _ in range(2):
        write_artifacts(cc, tmp_path, base="twice")
    rows = load_manifest(tmp_path)
    assert [r["name"] for r in rows] == ["twice"]


def test_fleet_loads_and_routes(emit_dir):
    out, ccs = emit_dir
    fleet = ClassifierFleet.from_emit_dir(out, backends="swar", max_batch=32)
    try:
        assert fleet.tenants == sorted(TOY_TENANTS)
        for name, (F, _, _, _) in TOY_TENANTS.items():
            assert fleet.n_features(name) == F
        with pytest.raises(KeyError):
            fleet.submit("nope", np.zeros(9))
        with pytest.raises(ValueError):
            fleet.submit("toy_a", np.zeros(5))       # wrong feature count
    finally:
        fleet.shutdown(drain=True)


def test_unknown_tenant_selection_and_duplicates(emit_dir):
    out, ccs = emit_dir
    with pytest.raises(KeyError):
        ClassifierFleet.from_emit_dir(out, tenants=["missing"])
    prog = CircuitProgram.from_classifier(ccs["toy_a"])
    spec = TenantSpec(name="dup", program=prog)
    with pytest.raises(ValueError):
        ClassifierFleet([spec, spec], warmup=False, autostart=False)
    with pytest.raises(ValueError):
        ClassifierFleet([TenantSpec(name="x", program=prog,
                                    backend="cuda")],
                        warmup=False, autostart=False)


# ---------------------------------------------------------------------------
# Soak: concurrent producers, multiple tenants, mixed backends
# ---------------------------------------------------------------------------
def test_soak_concurrent_producers_exactly_once_bit_identical(emit_dir):
    out, ccs = emit_dir
    deadline_ms = 150.0
    fleet = ClassifierFleet.from_emit_dir(
        out, backends={"toy_a": "np", "toy_b": "swar", "toy_c": "swar"},
        max_batch=64, deadline_ms=deadline_ms)
    n_producers = 4
    budget_s = 0.6
    pools = {name: np.random.default_rng(i).random((50, spec[0]))
             for i, (name, spec) in enumerate(sorted(TOY_TENANTS.items()))}
    names = sorted(TOY_TENANTS)
    submitted: list[list] = [[] for _ in range(n_producers)]

    def produce(w: int) -> None:
        rng = np.random.default_rng(1000 + w)
        t_end = time.perf_counter() + budget_s
        k = 0
        while time.perf_counter() < t_end:
            name = names[(w + k) % len(names)]           # interleave tenants
            idx = int(rng.integers(0, pools[name].shape[0]))
            req = fleet.submit(name, pools[name][idx])
            submitted[w].append((name, idx, req))
            k += 1
            if k % 7 == 0:                  # vary arrival pattern a little
                time.sleep(0.001)

    threads = [threading.Thread(target=produce, args=(w,))
               for w in range(n_producers)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        fleet.flush(timeout=30)
    finally:
        fleet.shutdown(drain=True)

    flat = [item for per_worker in submitted for item in per_worker]
    assert len(flat) > 0
    assert fleet.errors == []

    # answered exactly once: every handle completed, uids unique, and the
    # engines served exactly as many requests as were submitted
    uids = [req.uid for _, _, req in flat]
    assert len(set(uids)) == len(uids)
    assert all(req.done() and req.label is not None for _, _, req in flat)
    assert fleet.stats.n_requests == len(flat)
    per_tenant = {name: sum(1 for n, _, _ in flat if n == name)
                  for name in names}
    summaries = fleet.stats_summary()["tenants"]
    for name in names:
        assert summaries[name]["n_requests"] == per_tenant[name]

    # bit-identical to the offline program on every backend
    refs = {name: CircuitProgram.from_classifier(ccs[name]).predict(
        pools[name]) for name in names}
    for name, idx, req in flat:
        assert req.label == int(refs[name][idx]), (name, idx)

    # latency: nothing may overshoot its deadline by more than one
    # dispatch interval (worst observed batch) + scheduling slack.  The
    # slack floor is generous on purpose: a single-core CI worker running
    # the full suite has been observed to stall every thread of this
    # process ~1.5 s at a time, which is scheduler noise, not a
    # flush-policy bug — the policy itself is pinned timing-free by the
    # hypothesis tier, so this bound only has to catch a stuck scheduler.
    worst_batch_ms = max(summaries[name]["p99_ms"] for name in names)
    tol_ms = deadline_ms + max(2 * worst_batch_ms, 2_500.0)
    late = [(name, req.latency_ms) for name, _, req in flat
            if req.latency_ms > tol_ms]
    assert not late, f"requests busted deadline+interval: {late[:5]}"


def test_deadline_triggers_partial_flush(emit_dir):
    """A lone request (far below max_batch) must be served by its deadline
    without anyone calling flush — the scheduler's whole point."""
    out, _ = emit_dir
    fleet = ClassifierFleet.from_emit_dir(out, backends="swar",
                                          max_batch=256, deadline_ms=100.0)
    try:
        req = fleet.submit("toy_a", np.zeros(9))
        label = req.result(timeout=10.0)
        assert label is not None and req.latency_ms is not None
        # served once due, not held for max_batch company that never comes
        assert req.latency_ms < 5_000.0
    finally:
        fleet.shutdown(drain=True)


def test_shutdown_drains_backlog(emit_dir):
    out, ccs = emit_dir
    fleet = ClassifierFleet.from_emit_dir(out, backends="swar",
                                          max_batch=128,
                                          deadline_ms=60_000.0)
    x = np.random.default_rng(5).random((40, 9))
    reqs = [fleet.submit("toy_a", row) for row in x]
    fleet.shutdown(drain=True)          # far before any deadline
    ref = CircuitProgram.from_classifier(ccs["toy_a"]).predict(x)
    assert [r.label for r in reqs] == [int(v) for v in ref]
    with pytest.raises(RuntimeError):
        fleet.submit("toy_a", x[0])     # fleet is closed


def test_shutdown_cancel_completes_exceptionally(emit_dir):
    out, _ = emit_dir
    fleet = ClassifierFleet.from_emit_dir(out, backends="swar",
                                          max_batch=128,
                                          deadline_ms=60_000.0)
    req = fleet.submit("toy_b", np.zeros(6))
    fleet.shutdown(drain=False)
    assert req.done() and req.error is not None
    with pytest.raises(RuntimeError):
        req.result(timeout=1.0)


# ---------------------------------------------------------------------------
# Megakernel dispatch mode (fused multi-tenant pallas launches)
# ---------------------------------------------------------------------------
def test_megakernel_fuses_due_tenants_bit_identically(emit_dir):
    """All three toy tenants on the pallas backend, queues pre-loaded
    before the scheduler starts: the first fused pass must carry every
    tenant in ONE multi-program launch, and every label must match the
    offline `CircuitProgram.predict` reference."""
    out, ccs = emit_dir
    fleet = ClassifierFleet.from_emit_dir(
        out, backends="pallas", max_batch=64, deadline_ms=60_000.0,
        megakernel=True, autostart=False, warmup=False)
    rng = np.random.default_rng(17)
    handles = {}
    for name, (F, _, _, _) in TOY_TENANTS.items():
        x = rng.random((48, F))
        handles[name] = (x, [fleet.submit(name, row) for row in x])
    fleet.start()
    try:
        fleet.flush(timeout=60.0)
        for name, (x, reqs) in handles.items():
            ref = CircuitProgram.from_classifier(ccs[name]).predict(x)
            assert [r.result(timeout=60.0) for r in reqs] \
                == [int(v) for v in ref], name
        assert fleet.errors == []
        mk = fleet.stats_summary()["megakernel"]
        assert mk["launches"] >= 1
        assert mk["peak_tenants_per_launch"] == len(TOY_TENANTS), mk
        # per-tenant + fleet accounting both saw the fused traffic
        s = fleet.stats_summary()
        assert s["fleet"]["n_readings"] == 48 * len(TOY_TENANTS)
        for name in TOY_TENANTS:
            assert s["tenants"][name]["n_readings"] == 48
    finally:
        fleet.shutdown(drain=True)


def test_megakernel_only_fuses_pallas_backend(emit_dir):
    """Mixed-backend fleet with megakernel on: swar tenants keep their
    per-tenant dispatch path (and still serve correctly)."""
    out, ccs = emit_dir
    fleet = ClassifierFleet.from_emit_dir(
        out, backends={"toy_a": "pallas", "toy_b": "swar",
                       "toy_c": "pallas"},
        max_batch=64, deadline_ms=60_000.0, megakernel=True,
        autostart=False, warmup=False)
    rng = np.random.default_rng(23)
    handles = {}
    for name, (F, _, _, _) in TOY_TENANTS.items():
        x = rng.random((16, F))
        handles[name] = (x, [fleet.submit(name, row) for row in x])
    fleet.start()
    try:
        fleet.flush(timeout=60.0)
        for name, (x, reqs) in handles.items():
            ref = CircuitProgram.from_classifier(ccs[name]).predict(x)
            assert [r.result(timeout=60.0) for r in reqs] \
                == [int(v) for v in ref], name
        mk = fleet.stats_summary()["megakernel"]
        assert mk["peak_tenants_per_launch"] <= 2   # only the pallas pair
    finally:
        fleet.shutdown(drain=True)


def test_megakernel_rejects_worker_processes(emit_dir):
    out, _ = emit_dir
    with pytest.raises(ValueError, match="megakernel"):
        ClassifierFleet.from_emit_dir(out, backends="pallas",
                                      megakernel=True, workers=2,
                                      autostart=False, warmup=False)


# ---------------------------------------------------------------------------
# Hypothesis: the micro-batcher policy under arbitrary schedules
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    arrival = st.tuples(
        st.floats(0.0, 50.0, allow_nan=False),       # inter-arrival gap, ms
        st.floats(0.5, 200.0, allow_nan=False),      # deadline budget, ms
    )

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(st.integers(1, 8), st.lists(arrival, max_size=64),
           st.floats(0.0, 20.0, allow_nan=False))
    def test_microbatcher_order_size_drain(max_batch, arrivals, est_ms):
        """For arbitrary arrival orders / batch sizes / budgets: arrival
        order is preserved, no batch exceeds max_batch, due() never fires
        while the oldest request still has headroom, and shutdown drains
        to empty."""
        mb = MicroBatcher(max_batch, default_deadline_ms=50.0)
        est_s = est_ms * 1e-3
        now = 0.0
        seq = 0
        popped: list[int] = []
        for gap_ms, deadline_ms in arrivals:
            now += gap_ms * 1e-3
            mb.submit(seq, now, deadline_ms=deadline_ms)
            seq += 1
            while mb.due(now, est_s):
                batch = mb.pop_batch()
                assert 1 <= len(batch) <= max_batch
                popped.extend(e.item for e in batch)
            if len(mb):
                # not due: queue below max_batch and oldest has headroom
                assert len(mb) < max_batch
                assert now + est_s < mb.oldest_due_at
                # the advertised wakeup is exactly when due() flips
                wake = mb.next_due_at(est_s)
                assert wake is not None
                assert mb.due(wake + 1e-9, est_s)
                if wake - 1e-6 > now:
                    assert not mb.due(wake - 1e-6, est_s)
        for batch in mb.drain():                     # shutdown path
            assert 1 <= len(batch) <= max_batch
            popped.extend(e.item for e in batch)
        assert len(mb) == 0
        assert popped == list(range(seq))            # exactly once, in order
