"""Optimizers: AdamW semantics, 8-bit parity, grad-compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import adamw, adamw8bit
from repro.optim.adamw import AdamWConfig
from repro.optim.grad_compress import compress_grads, init_error_buffer


def _toy():
    params = {"a": jnp.asarray([1.0, -2.0, 3.0]),
              "b": {"w": jnp.ones((4, 4))}}
    grads = {"a": jnp.asarray([0.1, 0.2, -0.3]),
             "b": {"w": jnp.full((4, 4), 0.05)}}
    return params, grads


def test_adamw_first_step_direction():
    params, grads = _toy()
    cfg = AdamWConfig(lr=0.01, grad_clip=None)
    new, state = adamw.apply_updates(params, grads, adamw.init(params), cfg)
    # first Adam step moves each param by ~lr against the grad sign
    delta = np.asarray(new["a"] - params["a"])
    assert np.allclose(np.abs(delta), 0.01, atol=1e-3)
    assert (np.sign(delta) == -np.sign(np.asarray(grads["a"]))).all()
    assert int(state.step) == 1


def test_warmup_cosine_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.int32(0)))
    lr9 = float(adamw.schedule(cfg, jnp.int32(9)))
    lr_end = float(adamw.schedule(cfg, jnp.int32(99)))
    assert lr0 < lr9 <= 1.0
    assert 0.09 < lr_end < 0.2


def test_adamw8bit_parity_multi_step():
    params, grads = _toy()
    cfg = AdamWConfig(lr=0.01)
    p1, s1 = params, adamw.init(params)
    p2, s2 = params, adamw8bit.init(params)
    for _ in range(5):
        p1, s1 = adamw.apply_updates(p1, grads, s1, cfg)
        p2, s2 = adamw8bit.apply_updates(p2, grads, s2, cfg)
    d = max(float(jnp.max(jnp.abs(x - y)))
            for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3      # within int8 moment quantization error


def test_adamw8bit_memory_layout():
    params, _ = _toy()
    st8 = adamw8bit.init(params)
    leaves = jax.tree.leaves(st8.mu, is_leaf=lambda t: isinstance(
        t, adamw8bit.Q8Tensor))
    for q, p in zip(leaves, jax.tree.leaves(params)):
        assert q.codes.shape == p.shape        # shardable like the param
        assert q.codes.dtype == jnp.int8


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10000))
def test_grad_compress_error_feedback_unbiased(seed):
    """Over repeated identical grads, error feedback keeps the *cumulative*
    dequantized sum close to the true sum (bias does not accumulate)."""
    r = np.random.default_rng(seed)
    g = {"w": jnp.asarray(r.normal(0, 1, (32,)), jnp.float32)}
    err = init_error_buffer(g)
    total = jnp.zeros((32,))
    n = 8
    for _ in range(n):
        deq, err = compress_grads(g, err)
        total = total + deq["w"]
    drift = np.abs(np.asarray(total - n * g["w"])).max()
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert drift <= scale * 1.5 + 1e-6     # residual bounded by one quantum
