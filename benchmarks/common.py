"""Shared benchmark helpers: timing + TNN/PC library construction."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.cgp import evolve_pc_library
from repro.core.pcc import build_pcc_library, pc_pareto
from repro.core.tnn import TNNTrainConfig, train_tnn
from repro.data.tabular import DATASETS, make_dataset

QUICK = os.environ.get("REPRO_BENCH_FULL", "0") != "1"

_TNN_CACHE: dict = {}
_PC_CACHE: dict = {}


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def get_trained_tnn(dataset: str, seed: int = 0):
    """Train (and cache) the exact TNN at the paper's topology."""
    key = (dataset, seed)
    if key not in _TNN_CACHE:
        ds = make_dataset(dataset)
        spec = DATASETS[dataset]
        best = None
        lrs = (5e-3, 1e-2) if QUICK else (2e-3, 5e-3, 1e-2)
        for lr in lrs:
            t = train_tnn(ds, TNNTrainConfig(n_hidden=spec.topology[1],
                                             epochs=12 if QUICK else 18,
                                             lr=lr, seed=seed))
            if best is None or t.test_acc > best.test_acc:
                best = t
        _TNN_CACHE[key] = (ds, best)
    return _TNN_CACHE[key]


def get_pc_library(n: int, *, points: int | None = None,
                   iters: int | None = None, seed: int = 0):
    points = points if points is not None else (2 if QUICK else 4)
    iters = iters if iters is not None else (300 if QUICK else 1200)
    key = (n, points, iters, seed)
    if key not in _PC_CACHE:
        _PC_CACHE[key] = evolve_pc_library(n, n_points=points,
                                           max_iters=iters, seed=seed)
    return _PC_CACHE[key]


def tnn_libraries(dataset: str, seed: int = 0):
    """(ds, tnn, pcc_lib, pc_out_lib) with budgets scaled by QUICK."""
    ds, tnn = get_trained_tnn(dataset, seed)
    sizes, pcc_sizes = set(), []
    for (p, n) in tnn.hidden_sizes():
        if p >= 1 and n >= 1:
            sizes.update([p, n])
            pcc_sizes.append((p, n))
    out_n = max(tnn.out_nnz, 1)
    sizes.add(out_n)
    pc_libs = {n: get_pc_library(n, seed=seed) for n in sorted(sizes)}
    pcc_lib = build_pcc_library(sorted(set(pcc_sizes)), pc_libs,
                                n_samples=20000 if QUICK else 100000,
                                seed=seed)
    pc_out = pc_pareto(pc_libs[out_n])
    return ds, tnn, pcc_lib, pc_out
