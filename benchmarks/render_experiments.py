"""Render §Dry-run and §Roofline markdown tables from reports/dryrun.jsonl.

Usage: PYTHONPATH=src python -m benchmarks.render_experiments [path]
Prints markdown to stdout (pasted into EXPERIMENTS.md).
"""
from __future__ import annotations

import json
import sys


def load(path: str) -> dict:
    best = {}
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            key = (rec["arch"], rec["shape"], rec["mesh"],
                   rec.get("quant", "dense"), rec.get("remat", True),
                   rec.get("accum_dtype", "float32"),
                   rec.get("moe_fsdp", "d"),
                   rec.get("microbatches"))
            best[key] = rec
    return best


def baseline_only(best: dict) -> list[dict]:
    """Default-knob records only (the baseline table)."""
    out = {}
    for (arch, shape, mesh, quant, remat, acc, mf, mb), rec in best.items():
        if quant == "dense" and remat and acc == "float32" and mf == "d":
            out[(arch, shape, mesh)] = rec
    return [out[k] for k in sorted(out)]


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def main(path: str = "reports/dryrun.jsonl") -> None:
    rows = baseline_only(load(path))

    print("### Dry-run (baseline, default knobs)\n")
    print("| arch | shape | mesh | status | compile_s | params/dev GiB | "
          "temp GiB | collectives (top kinds) |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "ok":
            m, rf = r["memory"], r["roofline"]
            kinds = sorted(rf["collective_by_kind"].items(),
                           key=lambda kv: -kv[1])[:3]
            ks = ", ".join(f"{k}:{v/2**30:.2f}GiB" for k, v in kinds)
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r['compile_s']} | {fmt_bytes(m['argument_bytes'])} | "
                  f"{fmt_bytes(m['temp_bytes'])} | {ks} |")
        else:
            note = r.get("reason", r.get("error", ""))[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']} | - | - | - | {note} |")

    print("\n### Roofline (single-pod 16x16 = 256 chips, per-device terms)\n")
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | bound ms | MODEL_FLOPS/HLO_FLOPs | fits 16GB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != "16x16" or r["status"] != "ok":
            continue
        rf = r["roofline"]
        m = r["memory"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        peak = (m["argument_bytes"] + m["temp_bytes"]) / 2**30
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.1f} | "
              f"{rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.1f} | "
              f"{rf['dominant']} | {bound*1e3:.1f} | "
              f"{r['useful_flops_ratio']:.3f} | "
              f"{'yes' if peak <= 16 else f'NO ({peak:.0f}GiB)'} |")

    # skip list
    print("\n### Skipped cells\n")
    for r in rows:
        if r["status"] == "skipped" and r["mesh"] == "16x16":
            print(f"* {r['arch']} x {r['shape']}: {r['reason']}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun.jsonl")
