"""Fig. 8: NSGA-II convergence over generations (arrhythmia in the paper).

Validated claim: substantial progress within the first ~50 generations.
"""
from __future__ import annotations

import numpy as np

from repro.core.nsga2 import NSGA2Config
from repro.core.ternary import abc_binarize
from repro.core import tnn as T
from benchmarks.common import QUICK, tnn_libraries


def run(dataset: str = None) -> list[dict]:
    dataset = dataset or ("cardio" if QUICK else "arrhythmia")
    ds, tnn, pcc_lib, pc_out = tnn_libraries(dataset)
    xb = np.asarray(abc_binarize(ds.x_train, tnn.thresholds))
    prob = T.TNNApproxProblem(tnn=tnn, pcc_lib=pcc_lib, pc_out_lib=pc_out,
                              xbin=xb, y=ds.y_train)
    gens = 30 if QUICK else 200
    res = prob.optimize(NSGA2Config(pop_size=24 if QUICK else 40,
                                    n_generations=gens, seed=0))
    rows = []
    for g, best_err, best_area in res.history[:: max(1, gens // 20)]:
        rows.append({"bench": "fig8", "dataset": dataset, "generation": g,
                     "front_best_err": round(best_err, 4),
                     "front_best_area_mm2": round(best_area, 2)})
    first = res.history[0]
    last = res.history[-1]
    mid = res.history[min(len(res.history) - 1, max(1, gens // 4))]
    rows.append({"bench": "fig8_summary", "dataset": dataset,
                 "area_gen0": round(first[2], 2),
                 "area_quarter": round(mid[2], 2),
                 "area_final": round(last[2], 2),
                 "early_progress_frac": round(
                     (first[2] - mid[2]) / max(first[2] - last[2], 1e-9), 3)})
    return rows
