"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows: `name` is bench/row id,
`us_per_call` the wall time of producing that row's experiment, `derived`
a compact JSON payload with the row's metrics.

Env: REPRO_BENCH_FULL=1 switches from quick budgets to paper-scale budgets.
Usage: PYTHONPATH=src python -m benchmarks.run [--only table2,fig4,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCH_MODULES = {
    "table2": "benchmarks.table2_accuracy",
    "fig4": "benchmarks.fig4_pc_pareto",
    "fig5": "benchmarks.fig5_pcc_pareto",
    "fig6": "benchmarks.fig6_area_estimate",
    "fig7": "benchmarks.fig7_tnn_pareto",
    "fig8": "benchmarks.fig8_nsga2",
    "table3": "benchmarks.table3_sota",
    "variation": "benchmarks.variation_robustness",
    "roofline": "benchmarks.roofline_bench",
    "cgp": "benchmarks.cgp_throughput",
    "serve": "benchmarks.serve_throughput",
    "evolve": "benchmarks.evolve_campaign",
    "autopilot": "benchmarks.autopilot_loop",
}
BENCHES = list(BENCH_MODULES)


def _load(name: str):
    import importlib
    return importlib.import_module(BENCH_MODULES[name])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    names = [n.strip() for n in args.only.split(",")] if args.only else BENCHES
    unknown = [n for n in names if n not in BENCH_MODULES]
    if unknown:
        raise SystemExit(
            f"unknown bench name(s) {', '.join(unknown)}; "
            f"valid: {', '.join(BENCHES)}")

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.perf_counter()
        try:
            rows = _load(name).run()
            us = (time.perf_counter() - t0) * 1e6
            per_row = us / max(len(rows), 1)
            for row in rows:
                rid = row.pop("bench", name)
                extra = {k: v for k, v in row.items()}
                print(f"{rid},{per_row:.0f},{json.dumps(extra)}")
        except Exception as e:   # noqa: BLE001 — benches report and continue
            failures += 1
            us = (time.perf_counter() - t0) * 1e6
            print(f"{name},{us:.0f},{json.dumps({'error': str(e)[:200]})}")
            import traceback
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark groups failed")


if __name__ == "__main__":
    main()
