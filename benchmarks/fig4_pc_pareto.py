"""Fig. 4: CGP-approximate popcounts vs the truncation baseline.

Validated claim: at matched mean arithmetic error, CGP circuits are
substantially smaller than truncation (paper: ~2x at eps_mae 0.5/1.1/1.9
for 8/16/47-bit popcounts).
"""
from __future__ import annotations

import numpy as np

from repro.core.cgp import _truncation_stats
from repro.core.circuits import eval_vectors, popcount_netlist
from benchmarks.common import QUICK, get_pc_library


def run(sizes=None) -> list[dict]:
    sizes = sizes or ([8, 16] if QUICK else [8, 16, 47])
    rows = []
    for n in sizes:
        exact = popcount_netlist(n)
        ex_area = exact.cost().area_mm2
        packed, true = eval_vectors(n, n_samples=1 << 14)
        # truncation curve: all depths scored in one padded population pass
        trunc = {drop: (mae, area / ex_area)
                 for drop, (nl, mae, _, area)
                 in enumerate(_truncation_stats(n, packed, true), start=1)}
        lib = get_pc_library(n)
        for nl in lib[1:]:
            mae = nl.meta["mae"]
            rel = nl.cost().area_mm2 / ex_area
            # cheapest truncation whose error is no worse than this circuit's
            cands = [a for m, a in trunc.values() if m <= mae + 1e-9]
            trunc_rel = min(cands, default=1.0)
            rows.append({
                "bench": "fig4", "n": n, "method": "cgp",
                "mae": round(mae, 3), "wcae": nl.meta["wcae"],
                "rel_area": round(rel, 3),
                "trunc_rel_area_at_error": round(trunc_rel, 3),
                "cgp_wins": bool(rel < trunc_rel + 1e-9),
            })
        for drop, (mae, rel) in sorted(trunc.items())[:6]:
            rows.append({"bench": "fig4", "n": n, "method": f"trunc{drop}",
                         "mae": round(mae, 3), "rel_area": round(rel, 3)})
    return rows
