"""Roofline table from the dry-run artifacts (reports/dryrun.jsonl).

Reads every successful single-pod cell and emits the §Roofline rows:
three terms in seconds, dominant bottleneck, MODEL_FLOPS ratio.
"""
from __future__ import annotations

import json
import os


def run(path: str = "reports/dryrun.jsonl") -> list[dict]:
    if not os.path.exists(path):
        return [{"bench": "roofline", "note": f"{path} missing — run "
                 "`python -m repro.launch.dryrun --arch all --shape all`"}]
    best: dict[tuple, dict] = {}
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            key = (rec["arch"], rec["shape"], rec["mesh"],
                   rec.get("quant", "dense"))
            best[key] = rec        # last write wins (reruns override)
    rows = []
    for (arch, shape, mesh, quant), rec in sorted(best.items()):
        if rec["status"] != "ok":
            rows.append({"bench": "roofline", "arch": arch, "shape": shape,
                         "mesh": mesh, "quant": quant,
                         "status": rec["status"],
                         "note": rec.get("reason", rec.get("error", ""))[:90]})
            continue
        r = rec["roofline"]
        m = rec["memory"]
        rows.append({
            "bench": "roofline", "arch": arch, "shape": shape, "mesh": mesh,
            "quant": quant, "status": "ok",
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"],
            "hbm_gib_per_dev": round(m["peak_estimate_bytes"] / 2**30, 2),
            "useful_flops_ratio": round(rec["useful_flops_ratio"], 4),
            "compile_s": rec["compile_s"],
        })
    return rows
