"""Table 2: exact-TNN accuracy vs the exact-MLP baseline [37].

Validated claim: the TNN (1-bit inputs / ternary weights) stays within a
0-4% accuracy band of the 4-bit/8-bit MLP on every dataset.
"""
from __future__ import annotations

from repro.core.baselines import train_mlp_baseline
from repro.data.tabular import DATASETS
from benchmarks.common import QUICK, get_trained_tnn


def run(datasets=None) -> list[dict]:
    rows = []
    datasets = datasets or list(DATASETS)
    for name in datasets:
        spec = DATASETS[name]
        ds, tnn = get_trained_tnn(name)
        mlp = train_mlp_baseline(ds, hidden=spec.mlp_topology[1],
                                 epochs=10 if QUICK else 15)
        rows.append({
            "bench": "table2", "dataset": name,
            "tnn_acc": round(tnn.test_acc, 3),
            "mlp_acc": round(mlp.test_acc, 3),
            "delta": round(mlp.test_acc - tnn.test_acc, 3),
            "paper_tnn": spec.paper_tnn_acc, "paper_mlp": spec.paper_mlp_acc,
            "paper_delta": round(spec.paper_mlp_acc - spec.paper_tnn_acc, 3),
            "topology": "x".join(map(str, spec.topology)),
        })
    return rows
