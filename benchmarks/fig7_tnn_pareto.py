"""Fig. 7 (+ headline claims): NSGA-II approximate TNN area-accuracy Pareto.

Validated claims: (a) iso-accuracy approx TNNs cut area vs the exact TNN
(paper average: -41%); (b) allowing a 5% accuracy drop raises savings
(paper average: -67%).
"""
from __future__ import annotations

import numpy as np

from repro.core.nsga2 import NSGA2Config
from repro.core.ternary import abc_binarize
from repro.core import tnn as T
from benchmarks.common import QUICK, tnn_libraries


def run(datasets=None) -> list[dict]:
    datasets = datasets or (["cardio", "breast_cancer", "redwine"] if QUICK
                            else ["arrhythmia", "breast_cancer", "cardio",
                                  "redwine", "whitewine"])
    rows = []
    iso_savings, drop5_savings = [], []
    for name in datasets:
        ds, tnn, pcc_lib, pc_out = tnn_libraries(name)
        xb_tr = np.asarray(abc_binarize(ds.x_train, tnn.thresholds))
        xb_te = np.asarray(abc_binarize(ds.x_test, tnn.thresholds))
        prob = T.TNNApproxProblem(tnn=tnn, pcc_lib=pcc_lib, pc_out_lib=pc_out,
                                  xbin=xb_tr, y=ds.y_train)
        res = prob.optimize(NSGA2Config(
            pop_size=24 if QUICK else 40,
            n_generations=25 if QUICK else 120, seed=0))
        hx, ox = T.exact_netlists(tnn)
        exact_cost = T.tnn_hw_cost(tnn, hx, ox, interface=None)
        best_iso, best_drop5 = 1.0, 1.0
        for x, f in zip(res.pareto_x, res.pareto_f):
            hnl, onl = prob.decode(x)
            test_acc = float((T.predict_with_circuits(tnn, xb_te, hnl, onl)
                              == ds.y_test).mean())
            cost = T.tnn_hw_cost(tnn, hnl, onl, interface=None)
            rel = cost.area_mm2 / exact_cost.area_mm2
            rows.append({"bench": "fig7", "dataset": name,
                         "train_err": round(float(f[0]), 4),
                         "test_acc": round(test_acc, 4),
                         "area_cm2": round(cost.area_cm2, 4),
                         "power_mw": round(cost.power_mw, 4),
                         "rel_area": round(rel, 3)})
            if test_acc >= tnn.test_acc - 0.005:
                best_iso = min(best_iso, rel)
            if test_acc >= tnn.test_acc - 0.05:
                best_drop5 = min(best_drop5, rel)
        iso_savings.append(1 - best_iso)
        drop5_savings.append(1 - best_drop5)
        rows.append({"bench": "fig7_summary", "dataset": name,
                     "exact_acc": round(tnn.test_acc, 4),
                     "exact_area_cm2": round(exact_cost.area_cm2, 4),
                     "iso_acc_area_saving": round(1 - best_iso, 3),
                     "drop5_area_saving": round(1 - best_drop5, 3)})
    rows.append({"bench": "fig7_headline",
                 "avg_iso_saving": round(float(np.mean(iso_savings)), 3),
                 "avg_drop5_saving": round(float(np.mean(drop5_savings)), 3),
                 "paper_iso_saving": 0.41, "paper_drop5_saving": 0.67})
    return rows
