"""Table 3: comparison against the state of the art, w/ and w/o the
sensor-processor interface cost (ADC for MLPs, ABC for our TNNs).

Validated claims: (a) our exact/approx TNNs beat the modeled MLP baselines
on area and power; (b) interface accounting flips the balance dramatically
(paper: >=6x area / >=19x power vs the best Ax MLP once ADC vs ABC is
counted); (c) every non-arrhythmia TNN fits the printed-harvester budget.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines import PAPER_TABLE3, train_mlp_baseline
from repro.core.nsga2 import NSGA2Config
from repro.core.ternary import abc_binarize
from repro.core import tnn as T
from repro.data.tabular import DATASETS
from repro.hw.egfet import SENSOR_POWER_MW, power_source
from benchmarks.common import QUICK, tnn_libraries


def run(datasets=None) -> list[dict]:
    datasets = datasets or (["breast_cancer", "cardio"] if QUICK
                            else list(DATASETS))
    rows = []
    for name in datasets:
        spec = DATASETS[name]
        ds, tnn, pcc_lib, pc_out = tnn_libraries(name)

        # --- baselines (modeled) ---
        mlp = train_mlp_baseline(ds, hidden=spec.mlp_topology[1],
                                 epochs=10 if QUICK else 15)
        mlp_pow2 = train_mlp_baseline(ds, hidden=spec.mlp_topology[1],
                                      pow2=True, epochs=10 if QUICK else 15)
        for label, m in (("exact_mlp[37]", mlp), ("ax_mlp_pow2[1,2]", mlp_pow2)):
            c0 = m.cost(interface=None)
            c1 = m.cost(interface="adc4")
            rows.append({"bench": "table3", "dataset": name, "design": label,
                         "acc": round(m.test_acc, 3),
                         "area_cm2": round(c0.area_cm2, 3),
                         "power_mw": round(c0.power_mw, 3),
                         "area_cm2_iface": round(c1.area_cm2, 3),
                         "power_mw_iface": round(c1.power_mw, 3),
                         "power_source": power_source(
                             c1.power_mw + SENSOR_POWER_MW)})

        # --- our exact TNN ---
        hx, ox = T.exact_netlists(tnn)
        for label, (hnl, onl, acc) in {
                "our_exact_tnn": (hx, ox, tnn.test_acc)}.items():
            c0 = T.tnn_hw_cost(tnn, hnl, onl, interface=None)
            c1 = T.tnn_hw_cost(tnn, hnl, onl, interface="abc")
            rows.append({"bench": "table3", "dataset": name, "design": label,
                         "acc": round(acc, 3),
                         "area_cm2": round(c0.area_cm2, 3),
                         "power_mw": round(c0.power_mw, 3),
                         "area_cm2_iface": round(c1.area_cm2, 3),
                         "power_mw_iface": round(c1.power_mw, 3),
                         "power_source": power_source(
                             c1.power_mw + SENSOR_POWER_MW)})

        # --- our approximate TNN (best iso-accuracy point) ---
        xb_tr = np.asarray(abc_binarize(ds.x_train, tnn.thresholds))
        xb_te = np.asarray(abc_binarize(ds.x_test, tnn.thresholds))
        prob = T.TNNApproxProblem(tnn=tnn, pcc_lib=pcc_lib, pc_out_lib=pc_out,
                                  xbin=xb_tr, y=ds.y_train)
        res = prob.optimize(NSGA2Config(pop_size=24 if QUICK else 40,
                                        n_generations=20 if QUICK else 100,
                                        seed=0))
        best = None
        for x, f in zip(res.pareto_x, res.pareto_f):
            hnl, onl = prob.decode(x)
            acc = float((T.predict_with_circuits(tnn, xb_te, hnl, onl)
                         == ds.y_test).mean())
            c0 = T.tnn_hw_cost(tnn, hnl, onl, interface=None)
            if acc >= tnn.test_acc - 0.01:
                if best is None or c0.area_mm2 < best[1].area_mm2:
                    best = (acc, c0, T.tnn_hw_cost(tnn, hnl, onl, "abc"))
        if best:
            acc, c0, c1 = best
            rows.append({"bench": "table3", "dataset": name,
                         "design": "our_ax_tnn",
                         "acc": round(acc, 3),
                         "area_cm2": round(c0.area_cm2, 3),
                         "power_mw": round(c0.power_mw, 3),
                         "area_cm2_iface": round(c1.area_cm2, 3),
                         "power_mw_iface": round(c1.power_mw, 3),
                         "power_source": power_source(
                             c1.power_mw + SENSOR_POWER_MW)})

        # --- paper-published reference rows ---
        for design, (acc, area, power) in PAPER_TABLE3[name].items():
            rows.append({"bench": "table3_paper", "dataset": name,
                         "design": design, "acc": acc,
                         "area_cm2": area, "power_mw": power})
    return rows
