"""CGP fitness throughput: serial per-child loop vs population-parallel.

The acceptance metric for the batched evaluator: at lambda >= 16 the
`NetlistPopulation` path must sustain >= 5x the fitness evaluations/s of
the original per-child `Netlist.simulate` loop (identical work per eval:
simulate all packed vectors + decode + error stats + active-area cost).

Run directly to (re)generate the committed artifact:

    PYTHONPATH=src python -m benchmarks.cgp_throughput [BENCH_cgp.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core.cgp import (CGPConfig, _area_of, _errors, _mutate,
                            _population_of, _seed_genome, evolve_popcount)
from repro.core.circuits import eval_vectors, popcount_netlist, popcount_width
from benchmarks.common import QUICK


def _mutant_population(n: int, lam: int, seed: int = 0):
    """lam realistic CGP mutants of the exact n-input popcount."""
    rng = np.random.default_rng(seed)
    exact = popcount_netlist(n)
    cfg = CGPConfig(n_inputs=n, n_outputs=popcount_width(n),
                    n_nodes=exact.n_gates + 16, lam=lam)
    parent = _seed_genome(exact, cfg.n_nodes, rng, cfg.funcs)
    return [_mutate(parent, cfg, rng)[0] for _ in range(lam)]


def _time(fn, reps: int) -> float:
    fn()                                   # warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def measure(n: int, lam: int, reps: int, seed: int = 0) -> dict:
    genomes = _mutant_population(n, lam, seed)
    packed, true = eval_vectors(n)

    def serial():
        for g in genomes:
            _errors(g, packed, true)
            _area_of(g)

    def batched():
        pop = _population_of(genomes)
        pop.pc_errors(packed, true)
        pop.areas()

    t_serial = _time(serial, reps)
    t_batched = _time(batched, reps)
    row = {
        "bench": "cgp_throughput", "n": n, "lam": lam,
        "serial_evals_per_s": round(lam / t_serial, 1),
        "batched_evals_per_s": round(lam / t_batched, 1),
        "speedup": round(t_serial / t_batched, 2),
    }
    try:  # JAX uint32-SWAR twin (device-placeable); jit excluded via warmup
        from repro.kernels import circuit_sim as CS
        pop = _population_of(genomes)
        op32 = pop.op.astype(np.int32)
        w32 = CS.pack_words32(packed)
        t32 = true.astype(np.int32)

        def jax_path():
            mae, wc = CS.population_pc_errors(op32, pop.in0, pop.in1,
                                              pop.outputs, w32, t32,
                                              pop.n_inputs)
            mae.block_until_ready()

        row["jax_evals_per_s"] = round(lam / _time(jax_path, reps), 1)
    except Exception as e:  # noqa: BLE001 — jax path is informational
        row["jax_error"] = str(e)[:80]
    return row


def measure_evolution(n: int, lam: int, iters: int, seed: int = 0) -> dict:
    """End-to-end evolve_popcount wall-clock, batched vs serial loop."""
    packed_true = eval_vectors(n)

    def run(batch: bool):
        cfg = CGPConfig(n_inputs=n, n_outputs=popcount_width(n),
                        n_nodes=popcount_netlist(n).n_gates + 16,
                        tau=0.5, max_iters=iters, seed=seed, lam=lam,
                        batch_eval=batch)
        t0 = time.perf_counter()
        res = evolve_popcount(cfg, eval_set=packed_true)
        return res, time.perf_counter() - t0

    res_b, t_b = run(True)
    res_s, t_s = run(False)
    assert res_b.best_area == res_s.best_area      # identical trajectories
    return {
        "bench": "cgp_throughput_e2e", "n": n, "lam": lam, "iters": iters,
        "serial_evals_per_s": round(res_s.evaluations / t_s, 1),
        "batched_evals_per_s": round(res_b.evaluations / t_b, 1),
        "speedup": round(t_s / t_b, 2),
        "best_area": res_b.best_area,
    }


def run(sizes=None) -> list[dict]:
    reps = 3 if QUICK else 10
    combos = sizes or ([(8, 16), (8, 32), (12, 32)] if QUICK
                       else [(8, 16), (8, 32), (8, 64), (12, 32), (16, 32)])
    rows = [measure(n, lam, reps) for (n, lam) in combos]
    rows.append(measure_evolution(8, 16, 60 if QUICK else 200))
    return rows


def main(out_path: str = "BENCH_cgp.json") -> None:
    rows = run()
    payload = {"bench": "cgp_throughput",
               "note": "fitness evals/s, serial per-child Netlist loop vs "
                       "population-parallel NetlistPopulation (same work)",
               "rows": rows}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    for r in rows:
        print(r)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_cgp.json")
