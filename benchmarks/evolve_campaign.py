"""Campaign fitness-eval throughput: np vs SWAR (PR 1 baseline) vs Pallas.

Two workload shapes, both measured as fitness evaluations per second:

  * ``circuit``  — raw population x packed-word gate simulation (the CGP
    mutant workload of BENCH_cgp.json) through `repro.evolve.evaluator`'s
    three backends.  ``swar`` is the PR 1 `lax.scan` device path — the
    baseline the acceptance criterion names; ``pallas`` is the new kernel
    (compiled on TPU, interpret-mode on this CPU container, where the scan
    remains the fastest device path — the JSON records both honestly).
  * ``tnn_objective`` — the real campaign objective: full population NSGA-II
    fitness (hidden-gene gathers + output-plane gate sim + argmax accuracy)
    for a Table-2 problem, per eval backend.
  * ``campaign`` — end-to-end island-campaign wall clock on the synthetic
    problem: generations/s including migration, archive folding and
    checkpointing.
  * ``evolve_parallel`` — the island executor's scaling story: the same
    campaign stepped serially vs over 2 and 4 spawned workers, on a synth
    problem whose ``wait_ms`` knob blocks per fitness row — the
    device-dispatch stand-in for an expensive objective (this container
    has one visible core, so blocking overlap is the scaling the
    executor can honestly demonstrate here).  ``speedup_4w >= 2`` is the
    acceptance criterion the committed row pins.
  * ``zoo_compile`` — the batch compiler cold vs warm: a tiny
    dataset x variant sweep built from scratch (phase cache + campaigns +
    emit), then rebuilt with everything cached (manifest fingerprint
    skip), plus a forced recompile that still rides the warm phase cache.

Run directly to (re)generate the committed artifact:

    PYTHONPATH=src python -m benchmarks.evolve_campaign [BENCH_evolve.json]
"""
from __future__ import annotations

import json
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import QUICK
from benchmarks.cgp_throughput import _mutant_population, _time
from repro.core.cgp import _population_of
from repro.core.circuits import eval_vectors
from repro.evolve import Campaign, CampaignConfig, build_synth_problem
from repro.evolve.evaluator import BACKENDS, population_pc_errors


def measure_circuit(n: int, lam: int, reps: int, seed: int = 0) -> dict:
    pop = _population_of(_mutant_population(n, lam, seed))
    packed, true = eval_vectors(n)
    row = {"bench": "evolve_eval", "n": n, "lam": lam}
    for backend in BACKENDS:
        def run(b=backend):
            mae, _ = population_pc_errors(pop, packed, true, backend=b)
            np.asarray(mae)
        row[f"{backend}_evals_per_s"] = round(lam / _time(run, reps), 1)
    row["pallas_vs_swar"] = round(row["pallas_evals_per_s"]
                                  / row["swar_evals_per_s"], 3)
    return row


def measure_fused_kernel(n: int, lam: int, reps: int, seed: int = 0) -> dict:
    """Fused megakernel vs the pre-fusion two-stage Pallas path.

    Apples-to-apples on one host, one run: ``unfused`` reconstructs the
    old `population_eval_uint` (kernel emits output *words*, then the
    host-side Python loop builds one `(P, W, 32)` int32 plane per output
    bit); ``fused`` is the single `pallas_call` whose decode never leaves
    the kernel.  The committed BENCH row is the measured evidence behind
    the fused-decode acceptance criterion.
    """
    import jax.numpy as jnp

    from repro.kernels import circuit_sim as CS
    from repro.kernels import pallas_circuit_sim as PS

    pop = _population_of(_mutant_population(n, lam, seed))
    packed, _ = eval_vectors(n)
    words32 = CS.pack_words32(packed)
    plan = (pop.op.astype(np.int16), pop.in0, pop.in1, pop.outputs)
    n_out = pop.outputs.shape[1]

    def run_unfused():
        outw = PS.simulate_population(*plan, words32, pop.n_inputs)
        P, _, W = outw.shape
        shifts = jnp.arange(32, dtype=jnp.uint32)
        acc = jnp.zeros((P, W, 32), dtype=jnp.int32)
        for o in range(n_out):
            bits = ((outw[:, o, :, None] >> shifts)
                    & jnp.uint32(1)).astype(jnp.int32)
            acc = acc + (bits << o)
        np.asarray(acc.reshape(P, W * 32))

    def run_fused():
        np.asarray(PS.fused_eval_uint(*plan, words32, pop.n_inputs))

    row = {"bench": "evolve_fused_kernel", "n": n, "lam": lam}
    for name, fn in (("unfused", run_unfused), ("fused", run_fused)):
        fn()                                   # compile outside the timer
        row[f"{name}_evals_per_s"] = round(lam / _time(fn, reps), 1)
    row["fused_vs_unfused"] = round(row["fused_evals_per_s"]
                                    / row["unfused_evals_per_s"], 3)
    return row


def roofline_rows(combos) -> list[dict]:
    """Analytic roofline placement per kernel variant, per workload shape
    (plus one padded multi-tenant fleet launch) — see
    `repro.roofline.kernel_model` for the traffic model."""
    from repro.roofline.kernel_model import (CircuitShape, fleet_roofline,
                                             variant_rows)
    rows = []
    for (n, lam) in combos:
        pop = _population_of(_mutant_population(n, lam, 0))
        packed, _ = eval_vectors(n)
        shape = CircuitShape(P=pop.op.shape[0], G=pop.op.shape[1],
                             n_in=pop.n_inputs, W=2 * packed.shape[1],
                             n_out=pop.outputs.shape[1])
        for v in variant_rows(shape):
            rows.append({"bench": "kernel_roofline", "n": n, "lam": lam, **v})
    # a 4-tenant serving-fleet launch at max_batch=1024 (32 words/tenant)
    tenant_shapes = [CircuitShape(P=1, G=g, n_in=f, W=32, n_out=o)
                     for g, f, o in ((180, 21, 64), (340, 30, 32),
                                     (260, 11, 32), (260, 11, 32))]
    rl, eff = fleet_roofline(tenant_shapes)
    rows.append({"bench": "kernel_roofline", "variant": "fleet_megakernel",
                 "tenants": len(tenant_shapes), "ops": rl.flops,
                 "hbm_bytes": rl.bytes_accessed,
                 "arith_intensity": round(rl.flops / rl.bytes_accessed, 3),
                 "dominant": rl.dominant, "bound_s": rl.bound_s,
                 "padding_efficiency": round(eff, 3)})
    return rows


def measure_tnn_objective(dataset: str, pop_size: int, reps: int) -> dict:
    from repro.evolve.problems import build_tnn_problem
    prob = build_tnn_problem(dataset, epochs=4 if QUICK else 12,
                             cgp_iters=60 if QUICK else 500,
                             pcc_samples=4000 if QUICK else 30000)
    rng = np.random.default_rng(0)
    pop = np.stack([rng.integers(0, prob.domains) for _ in range(pop_size)])
    row = {"bench": "evolve_tnn_objective", "dataset": dataset,
           "pop": pop_size, "n_genes": int(prob.domains.shape[0])}
    for backend in BACKENDS:
        prob.approx.eval_backend = backend
        row[f"{backend}_evals_per_s"] = round(
            pop_size / _time(lambda: prob.objective(pop), reps), 1)
    row["pallas_vs_swar"] = round(row["pallas_evals_per_s"]
                                  / row["swar_evals_per_s"], 3)
    return row


def measure_campaign(reps: int) -> dict:
    p = build_synth_problem()
    cfg = CampaignConfig(n_islands=4, pop_size=16, n_epochs=4,
                         gens_per_epoch=4, migrate_k=2, seed=0)

    def run():
        with tempfile.TemporaryDirectory() as d:
            Campaign(p.domains, p.objective, cfg, checkpoint_dir=d,
                     name=p.name).run()

    t = _time(run, reps)
    gens = cfg.n_islands * cfg.total_generations
    return {"bench": "evolve_campaign", "islands": cfg.n_islands,
            "pop": cfg.pop_size, "generations": gens,
            "wall_s": round(t, 3), "gens_per_s": round(gens / t, 1),
            "fitness_evals_per_s": round(
                gens * cfg.pop_size / t, 1)}


def measure_parallel_campaign(epochs: int, wait_ms: float = 1.0) -> dict:
    """Serial vs 2- vs 4-worker epoch stepping on one expensive objective.

    Each mode steps the *same* campaign shape for `epochs` epochs after a
    warm-up epoch (executor spawn + worker problem builds stay out of the
    timed region — that cost is amortized over a real campaign's life).
    The objective blocks ``wait_ms`` per evaluated row — the
    device-dispatch stand-in (`build_synth_problem(wait_ms=...)`): this
    container exposes a single CPU core, so only a *blocking* objective
    can demonstrate the executor's overlap; the committed row measures
    exactly that.  Parallel workers keep per-worker memo caches, so they
    lose the cross-island dedup hits the serial memo gets — the measured
    speedup is net of that (honest, not best-case).
    """
    from repro.evolve.problems import ProblemSpec

    spec = ProblemSpec("synth", {"n_genes": 10, "domain": 6,
                                 "wait_ms": wait_ms})
    row = {"bench": "evolve_parallel", "islands": 4, "pop": 16,
           "gens_per_epoch": 5, "epochs": epochs, "wait_ms": wait_ms}
    gens = 4 * 5 * epochs
    for workers in (0, 2, 4):
        p = spec.build()
        cfg = CampaignConfig(n_islands=4, pop_size=16,
                             n_epochs=epochs + 1, gens_per_epoch=5,
                             migrate_k=2, seed=0, workers=workers)
        with Campaign(p.domains, p.objective, cfg, name=p.name,
                      problem_spec=spec) as c:
            c.step_epoch()                     # warm-up: spawn + init
            t0 = time.perf_counter()
            for _ in range(epochs):
                c.step_epoch()
            t = time.perf_counter() - t0
        key = "serial" if workers == 0 else f"workers{workers}"
        row[f"{key}_wall_s"] = round(t, 3)
        row[f"{key}_gens_per_s"] = round(gens / t, 1)
    row["speedup_2w"] = round(row["workers2_gens_per_s"]
                              / row["serial_gens_per_s"], 3)
    row["speedup_4w"] = round(row["workers4_gens_per_s"]
                              / row["serial_gens_per_s"], 3)
    return row


def measure_zoo_compile() -> dict:
    """Cold vs warm zoo build on a tiny sweep (1 dataset x 2 variants).

    ``cold_s``   — empty emit dir + empty phase cache: trains, searches,
                   compiles and emits everything.
    ``warm_s``   — identical second invocation: every entry's manifest
                   fingerprint matches and its bundle verifies, so the
                   build is pure skip (the >=10x acceptance criterion).
    ``forced_s`` — ``force=True`` with the phase cache still warm: full
                   campaign + emit per entry, but Phase 1/2 is a cache
                   load — isolates what the phase cache alone buys.
    """
    import shutil

    from repro.compile.zoo import build_zoo, make_entries
    from repro.evolve.problems import clear_phase_memo

    budgets = dict(islands=2, pop=8, epochs=1, gens_per_epoch=2,
                   migrate_k=1, tnn_epochs=2, cgp_points=1, cgp_iters=30,
                   pcc_samples=500)
    entries = make_entries(["breast_cancer"], ["base", "lean"], **budgets)
    emit = tempfile.mkdtemp(prefix="bench_zoo_emit_")
    cache = tempfile.mkdtemp(prefix="bench_zoo_phase_")
    row = {"bench": "zoo_compile", "entries": len(entries), **budgets}
    try:
        clear_phase_memo()      # genuinely cold: no in-process products
        t0 = time.perf_counter()
        rep = build_zoo(entries, emit, workers=1, cache_dir=cache)
        row["cold_s"] = round(time.perf_counter() - t0, 3)
        row["cold_built"] = len(rep["built"])
        t0 = time.perf_counter()
        rep = build_zoo(entries, emit, workers=1, cache_dir=cache)
        row["warm_s"] = round(time.perf_counter() - t0, 3)
        row["warm_cached"] = len(rep["cached"])
        clear_phase_memo()      # forced path rides the *disk* cache only
        t0 = time.perf_counter()
        build_zoo(entries, emit, workers=1, cache_dir=cache, force=True)
        row["forced_s"] = round(time.perf_counter() - t0, 3)
    finally:
        shutil.rmtree(emit, ignore_errors=True)
        shutil.rmtree(cache, ignore_errors=True)
    row["warm_speedup"] = round(row["cold_s"] / max(row["warm_s"], 1e-3), 1)
    row["forced_speedup"] = round(row["cold_s"] / row["forced_s"], 2)
    return row


def run(combos=None) -> list[dict]:
    reps = 3 if QUICK else 10
    combos = combos or ([(8, 32), (12, 32)] if QUICK
                        else [(8, 16), (8, 32), (8, 64), (12, 32)])
    rows = [measure_circuit(n, lam, reps) for (n, lam) in combos]
    rows += [measure_fused_kernel(n, lam, reps) for (n, lam) in combos]
    rows += roofline_rows(combos)
    rows.append(measure_tnn_objective("breast_cancer", 24, reps))
    rows.append(measure_campaign(max(1, reps // 3)))
    rows.append(measure_parallel_campaign(epochs=2 if QUICK else 4))
    rows.append(measure_zoo_compile())
    return rows


def main(out_path: str = "BENCH_evolve.json") -> None:
    t0 = time.perf_counter()
    rows = run()
    payload = {
        "bench": "evolve_campaign",
        "note": "campaign fitness evals/s: np (NetlistPopulation) vs swar "
                "(PR 1 lax.scan baseline) vs pallas "
                "(kernels.pallas_circuit_sim; interpret-mode on CPU, "
                "compiled on TPU), plus end-to-end island-campaign rate",
        "backend": "cpu-interpret" if _cpu() else "tpu",
        "rows": rows,
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    for r in rows:
        print(r)
    print(f"wrote {out_path}")


def _cpu() -> bool:
    import jax
    return jax.default_backend() != "tpu"


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_evolve.json")
