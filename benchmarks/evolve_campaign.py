"""Campaign fitness-eval throughput: np vs SWAR (PR 1 baseline) vs Pallas.

Two workload shapes, both measured as fitness evaluations per second:

  * ``circuit``  — raw population x packed-word gate simulation (the CGP
    mutant workload of BENCH_cgp.json) through `repro.evolve.evaluator`'s
    three backends.  ``swar`` is the PR 1 `lax.scan` device path — the
    baseline the acceptance criterion names; ``pallas`` is the new kernel
    (compiled on TPU, interpret-mode on this CPU container, where the scan
    remains the fastest device path — the JSON records both honestly).
  * ``tnn_objective`` — the real campaign objective: full population NSGA-II
    fitness (hidden-gene gathers + output-plane gate sim + argmax accuracy)
    for a Table-2 problem, per eval backend.
  * ``campaign`` — end-to-end island-campaign wall clock on the synthetic
    problem: generations/s including migration, archive folding and
    checkpointing.

Run directly to (re)generate the committed artifact:

    PYTHONPATH=src python -m benchmarks.evolve_campaign [BENCH_evolve.json]
"""
from __future__ import annotations

import json
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import QUICK
from benchmarks.cgp_throughput import _mutant_population, _time
from repro.core.cgp import _population_of
from repro.core.circuits import eval_vectors
from repro.evolve import Campaign, CampaignConfig, build_synth_problem
from repro.evolve.evaluator import BACKENDS, population_pc_errors


def measure_circuit(n: int, lam: int, reps: int, seed: int = 0) -> dict:
    pop = _population_of(_mutant_population(n, lam, seed))
    packed, true = eval_vectors(n)
    row = {"bench": "evolve_eval", "n": n, "lam": lam}
    for backend in BACKENDS:
        def run(b=backend):
            mae, _ = population_pc_errors(pop, packed, true, backend=b)
            np.asarray(mae)
        row[f"{backend}_evals_per_s"] = round(lam / _time(run, reps), 1)
    row["pallas_vs_swar"] = round(row["pallas_evals_per_s"]
                                  / row["swar_evals_per_s"], 3)
    return row


def measure_tnn_objective(dataset: str, pop_size: int, reps: int) -> dict:
    from repro.evolve.problems import build_tnn_problem
    prob = build_tnn_problem(dataset, epochs=4 if QUICK else 12,
                             cgp_iters=60 if QUICK else 500,
                             pcc_samples=4000 if QUICK else 30000)
    rng = np.random.default_rng(0)
    pop = np.stack([rng.integers(0, prob.domains) for _ in range(pop_size)])
    row = {"bench": "evolve_tnn_objective", "dataset": dataset,
           "pop": pop_size, "n_genes": int(prob.domains.shape[0])}
    for backend in BACKENDS:
        prob.approx.eval_backend = backend
        row[f"{backend}_evals_per_s"] = round(
            pop_size / _time(lambda: prob.objective(pop), reps), 1)
    row["pallas_vs_swar"] = round(row["pallas_evals_per_s"]
                                  / row["swar_evals_per_s"], 3)
    return row


def measure_campaign(reps: int) -> dict:
    p = build_synth_problem()
    cfg = CampaignConfig(n_islands=4, pop_size=16, n_epochs=4,
                         gens_per_epoch=4, migrate_k=2, seed=0)

    def run():
        with tempfile.TemporaryDirectory() as d:
            Campaign(p.domains, p.objective, cfg, checkpoint_dir=d,
                     name=p.name).run()

    t = _time(run, reps)
    gens = cfg.n_islands * cfg.total_generations
    return {"bench": "evolve_campaign", "islands": cfg.n_islands,
            "pop": cfg.pop_size, "generations": gens,
            "wall_s": round(t, 3), "gens_per_s": round(gens / t, 1),
            "fitness_evals_per_s": round(
                gens * cfg.pop_size / t, 1)}


def run(combos=None) -> list[dict]:
    reps = 3 if QUICK else 10
    combos = combos or ([(8, 32), (12, 32)] if QUICK
                        else [(8, 16), (8, 32), (8, 64), (12, 32)])
    rows = [measure_circuit(n, lam, reps) for (n, lam) in combos]
    rows.append(measure_tnn_objective("breast_cancer", 24, reps))
    rows.append(measure_campaign(max(1, reps // 3)))
    return rows


def main(out_path: str = "BENCH_evolve.json") -> None:
    t0 = time.perf_counter()
    rows = run()
    payload = {
        "bench": "evolve_campaign",
        "note": "campaign fitness evals/s: np (NetlistPopulation) vs swar "
                "(PR 1 lax.scan baseline) vs pallas "
                "(kernels.pallas_circuit_sim; interpret-mode on CPU, "
                "compiled on TPU), plus end-to-end island-campaign rate",
        "backend": "cpu-interpret" if _cpu() else "tpu",
        "rows": rows,
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    for r in rows:
        print(r)
    print(f"wrote {out_path}")


def _cpu() -> bool:
    import jax
    return jax.default_backend() != "tpu"


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_evolve.json")
