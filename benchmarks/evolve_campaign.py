"""Campaign fitness-eval throughput: np vs SWAR (PR 1 baseline) vs Pallas.

Two workload shapes, both measured as fitness evaluations per second:

  * ``circuit``  — raw population x packed-word gate simulation (the CGP
    mutant workload of BENCH_cgp.json) through `repro.evolve.evaluator`'s
    three backends.  ``swar`` is the PR 1 `lax.scan` device path — the
    baseline the acceptance criterion names; ``pallas`` is the new kernel
    (compiled on TPU, interpret-mode on this CPU container, where the scan
    remains the fastest device path — the JSON records both honestly).
  * ``tnn_objective`` — the real campaign objective: full population NSGA-II
    fitness (hidden-gene gathers + output-plane gate sim + argmax accuracy)
    for a Table-2 problem, per eval backend.
  * ``campaign`` — end-to-end island-campaign wall clock on the synthetic
    problem: generations/s including migration, archive folding and
    checkpointing.

Run directly to (re)generate the committed artifact:

    PYTHONPATH=src python -m benchmarks.evolve_campaign [BENCH_evolve.json]
"""
from __future__ import annotations

import json
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import QUICK
from benchmarks.cgp_throughput import _mutant_population, _time
from repro.core.cgp import _population_of
from repro.core.circuits import eval_vectors
from repro.evolve import Campaign, CampaignConfig, build_synth_problem
from repro.evolve.evaluator import BACKENDS, population_pc_errors


def measure_circuit(n: int, lam: int, reps: int, seed: int = 0) -> dict:
    pop = _population_of(_mutant_population(n, lam, seed))
    packed, true = eval_vectors(n)
    row = {"bench": "evolve_eval", "n": n, "lam": lam}
    for backend in BACKENDS:
        def run(b=backend):
            mae, _ = population_pc_errors(pop, packed, true, backend=b)
            np.asarray(mae)
        row[f"{backend}_evals_per_s"] = round(lam / _time(run, reps), 1)
    row["pallas_vs_swar"] = round(row["pallas_evals_per_s"]
                                  / row["swar_evals_per_s"], 3)
    return row


def measure_fused_kernel(n: int, lam: int, reps: int, seed: int = 0) -> dict:
    """Fused megakernel vs the pre-fusion two-stage Pallas path.

    Apples-to-apples on one host, one run: ``unfused`` reconstructs the
    old `population_eval_uint` (kernel emits output *words*, then the
    host-side Python loop builds one `(P, W, 32)` int32 plane per output
    bit); ``fused`` is the single `pallas_call` whose decode never leaves
    the kernel.  The committed BENCH row is the measured evidence behind
    the fused-decode acceptance criterion.
    """
    import jax.numpy as jnp

    from repro.kernels import circuit_sim as CS
    from repro.kernels import pallas_circuit_sim as PS

    pop = _population_of(_mutant_population(n, lam, seed))
    packed, _ = eval_vectors(n)
    words32 = CS.pack_words32(packed)
    plan = (pop.op.astype(np.int16), pop.in0, pop.in1, pop.outputs)
    n_out = pop.outputs.shape[1]

    def run_unfused():
        outw = PS.simulate_population(*plan, words32, pop.n_inputs)
        P, _, W = outw.shape
        shifts = jnp.arange(32, dtype=jnp.uint32)
        acc = jnp.zeros((P, W, 32), dtype=jnp.int32)
        for o in range(n_out):
            bits = ((outw[:, o, :, None] >> shifts)
                    & jnp.uint32(1)).astype(jnp.int32)
            acc = acc + (bits << o)
        np.asarray(acc.reshape(P, W * 32))

    def run_fused():
        np.asarray(PS.fused_eval_uint(*plan, words32, pop.n_inputs))

    row = {"bench": "evolve_fused_kernel", "n": n, "lam": lam}
    for name, fn in (("unfused", run_unfused), ("fused", run_fused)):
        fn()                                   # compile outside the timer
        row[f"{name}_evals_per_s"] = round(lam / _time(fn, reps), 1)
    row["fused_vs_unfused"] = round(row["fused_evals_per_s"]
                                    / row["unfused_evals_per_s"], 3)
    return row


def roofline_rows(combos) -> list[dict]:
    """Analytic roofline placement per kernel variant, per workload shape
    (plus one padded multi-tenant fleet launch) — see
    `repro.roofline.kernel_model` for the traffic model."""
    from repro.roofline.kernel_model import (CircuitShape, fleet_roofline,
                                             variant_rows)
    rows = []
    for (n, lam) in combos:
        pop = _population_of(_mutant_population(n, lam, 0))
        packed, _ = eval_vectors(n)
        shape = CircuitShape(P=pop.op.shape[0], G=pop.op.shape[1],
                             n_in=pop.n_inputs, W=2 * packed.shape[1],
                             n_out=pop.outputs.shape[1])
        for v in variant_rows(shape):
            rows.append({"bench": "kernel_roofline", "n": n, "lam": lam, **v})
    # a 4-tenant serving-fleet launch at max_batch=1024 (32 words/tenant)
    tenant_shapes = [CircuitShape(P=1, G=g, n_in=f, W=32, n_out=o)
                     for g, f, o in ((180, 21, 64), (340, 30, 32),
                                     (260, 11, 32), (260, 11, 32))]
    rl, eff = fleet_roofline(tenant_shapes)
    rows.append({"bench": "kernel_roofline", "variant": "fleet_megakernel",
                 "tenants": len(tenant_shapes), "ops": rl.flops,
                 "hbm_bytes": rl.bytes_accessed,
                 "arith_intensity": round(rl.flops / rl.bytes_accessed, 3),
                 "dominant": rl.dominant, "bound_s": rl.bound_s,
                 "padding_efficiency": round(eff, 3)})
    return rows


def measure_tnn_objective(dataset: str, pop_size: int, reps: int) -> dict:
    from repro.evolve.problems import build_tnn_problem
    prob = build_tnn_problem(dataset, epochs=4 if QUICK else 12,
                             cgp_iters=60 if QUICK else 500,
                             pcc_samples=4000 if QUICK else 30000)
    rng = np.random.default_rng(0)
    pop = np.stack([rng.integers(0, prob.domains) for _ in range(pop_size)])
    row = {"bench": "evolve_tnn_objective", "dataset": dataset,
           "pop": pop_size, "n_genes": int(prob.domains.shape[0])}
    for backend in BACKENDS:
        prob.approx.eval_backend = backend
        row[f"{backend}_evals_per_s"] = round(
            pop_size / _time(lambda: prob.objective(pop), reps), 1)
    row["pallas_vs_swar"] = round(row["pallas_evals_per_s"]
                                  / row["swar_evals_per_s"], 3)
    return row


def measure_campaign(reps: int) -> dict:
    p = build_synth_problem()
    cfg = CampaignConfig(n_islands=4, pop_size=16, n_epochs=4,
                         gens_per_epoch=4, migrate_k=2, seed=0)

    def run():
        with tempfile.TemporaryDirectory() as d:
            Campaign(p.domains, p.objective, cfg, checkpoint_dir=d,
                     name=p.name).run()

    t = _time(run, reps)
    gens = cfg.n_islands * cfg.total_generations
    return {"bench": "evolve_campaign", "islands": cfg.n_islands,
            "pop": cfg.pop_size, "generations": gens,
            "wall_s": round(t, 3), "gens_per_s": round(gens / t, 1),
            "fitness_evals_per_s": round(
                gens * cfg.pop_size / t, 1)}


def run(combos=None) -> list[dict]:
    reps = 3 if QUICK else 10
    combos = combos or ([(8, 32), (12, 32)] if QUICK
                        else [(8, 16), (8, 32), (8, 64), (12, 32)])
    rows = [measure_circuit(n, lam, reps) for (n, lam) in combos]
    rows += [measure_fused_kernel(n, lam, reps) for (n, lam) in combos]
    rows += roofline_rows(combos)
    rows.append(measure_tnn_objective("breast_cancer", 24, reps))
    rows.append(measure_campaign(max(1, reps // 3)))
    return rows


def main(out_path: str = "BENCH_evolve.json") -> None:
    t0 = time.perf_counter()
    rows = run()
    payload = {
        "bench": "evolve_campaign",
        "note": "campaign fitness evals/s: np (NetlistPopulation) vs swar "
                "(PR 1 lax.scan baseline) vs pallas "
                "(kernels.pallas_circuit_sim; interpret-mode on CPU, "
                "compiled on TPU), plus end-to-end island-campaign rate",
        "backend": "cpu-interpret" if _cpu() else "tpu",
        "rows": rows,
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    for r in rows:
        print(r)
    print(f"wrote {out_path}")


def _cpu() -> bool:
    import jax
    return jax.default_backend() != "tpu"


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_evolve.json")
