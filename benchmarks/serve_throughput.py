"""Sensor-stream serving throughput: single engine + multi-tenant fleet.

Single-engine section: compiles the cardio exact TNN (the paper's mid-size
Table-2 design) to a `CircuitProgram` and measures end-to-end engine
throughput — raw readings in, class labels out, including ABC
binarization, bit-packing and decode — at batch sizes {1, 64, 1024}, with
a numpy-backend row at the largest batch anchoring the jitted SWAR
speedup.

Fleet section: a 2-tenant `ClassifierFleet` (cardio + breast_cancer)
replays concurrent held-out streams from 4 producer threads through the
deadline-driven micro-batching scheduler, recording per-tenant and
fleet-wide rows (readings/s, request p50/p99, SLO misses) under
`bench == "serve_fleet"`.

Socket section: the same 2-tenant replay, but every reading crosses the
length-prefixed TCP transport (`serve/server.py` + `serve/client.py`) —
rows land under `bench == "serve_socket"`, so the in-process vs
cross-process overhead (readings/s and request p99) is one diff away.
Writes BENCH_serve.json.

Run directly to (re)generate the committed artifact:

    PYTHONPATH=src python -m benchmarks.serve_throughput [BENCH_serve.json]
"""
from __future__ import annotations

import json
import sys

import numpy as np

from benchmarks.common import QUICK, get_trained_tnn
from repro.core.tnn import exact_netlists
from repro.compile.ir import lower_classifier
from repro.compile.program import CircuitProgram
from repro.serve.engine import CircuitServingEngine

BATCH_SIZES = (1, 64, 1024)
FLEET_DATASETS = ("cardio", "breast_cancer")
FLEET_DEADLINE_MS = 250.0   # above the full-speed replay's queueing delay


def _stream(x_test: np.ndarray, n: int, seed: int = 0) -> np.ndarray:
    """n readings drawn (with wraparound) from the test distribution."""
    idx = np.random.default_rng(seed).integers(0, x_test.shape[0], size=n)
    return x_test[idx]


def _measure(prog: CircuitProgram, x_test: np.ndarray, batch: int,
             n_readings: int) -> dict:
    engine = CircuitServingEngine(prog, max_batch=batch)
    engine.warmup()
    engine.classify_stream(_stream(x_test, n_readings))
    s = engine.stats.summary()
    return {
        "batch": batch,
        "readings": s["n_readings"],
        "readings_per_s": s["readings_per_s"],
        "p50_ms": s["p50_ms"],
        "p99_ms": s["p99_ms"],
    }


def _fleet_specs_and_streams(n_readings: int):
    from repro.serve import TenantSpec

    specs, streams = [], {}
    for i, dataset in enumerate(FLEET_DATASETS):
        ds, tnn = get_trained_tnn(dataset)
        cc = lower_classifier(tnn, *exact_netlists(tnn))
        name = f"tnn_{dataset}"
        specs.append(TenantSpec(
            name=name, program=CircuitProgram.from_classifier(cc),
            backend="swar", max_batch=256, deadline_ms=FLEET_DEADLINE_MS,
            dataset=dataset))
        streams[name] = _stream(ds.x_test, n_readings, seed=i)
    return specs, streams


def _report_rows(bench: str, report: dict) -> list[dict]:
    rows = []
    for name, t in report["tenants"].items():
        rows.append({"bench": bench, "tenant": name,
                     "backend": t["backend"],
                     "deadline_ms": FLEET_DEADLINE_MS,
                     "readings": t["n_readings"],
                     "readings_per_s": t["readings_per_s"],
                     "req_p50_ms": t["req_p50_ms"],
                     "req_p99_ms": t["req_p99_ms"],
                     "n_slo_miss": t["n_slo_miss"],
                     "labels_match_offline": t["labels_match_offline"]})
    f = report["fleet"]
    rows.append({"bench": bench, "tenant": "__fleet__",
                 "backend": "swar", "deadline_ms": FLEET_DEADLINE_MS,
                 "readings": f["n_readings"],
                 "readings_per_s": f["readings_per_s"],
                 "req_p50_ms": f["req_p50_ms"],
                 "req_p99_ms": f["req_p99_ms"],
                 "n_slo_miss": f["n_slo_miss"],
                 "labels_match_offline": report["labels_match_offline"]})
    return rows


def _measure_fleet(n_readings: int) -> list[dict]:
    """2-tenant concurrent replay through the micro-batching scheduler."""
    from repro.serve import ClassifierFleet
    from repro.serve.__main__ import replay_fleet

    specs, streams = _fleet_specs_and_streams(n_readings)
    fleet = ClassifierFleet(specs)
    try:
        report = replay_fleet(fleet, streams, producers=4, timeout=600)
    finally:
        fleet.shutdown(drain=True)
    return _report_rows("serve_fleet", report)


def _measure_socket(n_readings: int) -> list[dict]:
    """The same 2-tenant replay, every reading over the TCP transport."""
    from repro.serve import ClassifierFleet
    from repro.serve.__main__ import replay_client
    from repro.serve.client import FleetClient
    from repro.serve.server import FleetServer

    specs, streams = _fleet_specs_and_streams(n_readings)
    fleet = ClassifierFleet(specs)
    server = FleetServer(fleet)
    try:
        host, port = server.start_background()
        with FleetClient(host, port) as client:
            report = replay_client(client, fleet, streams, producers=4,
                                   timeout=600)
    finally:
        server.stop()
        fleet.shutdown(drain=True)
    return _report_rows("serve_socket", report)


def run() -> list[dict]:
    ds, tnn = get_trained_tnn("cardio")
    cc = lower_classifier(tnn, *exact_netlists(tnn))
    prog = CircuitProgram.from_classifier(cc)

    rows = []
    for batch in BATCH_SIZES:
        n = (max(256, 4 * batch) if QUICK else max(4096, 64 * batch))
        row = {"bench": "serve", "backend": "jax",
               "gates": cc.ir.n_gates, "depth": cc.ir.depth,
               **_measure(prog, ds.x_test, batch, n)}
        rows.append(row)

    prog_np = CircuitProgram.from_classifier(cc, backend="np")
    n = 2048 if QUICK else 16384
    rows.append({"bench": "serve", "backend": "np",
                 "gates": cc.ir.n_gates, "depth": cc.ir.depth,
                 **_measure(prog_np, ds.x_test, 1024, n)})

    rows.extend(_measure_fleet(2048 if QUICK else 16384))
    rows.extend(_measure_socket(2048 if QUICK else 16384))

    out = sys.argv[1] if (__name__ == "__main__" and len(sys.argv) > 1) \
        else "BENCH_serve.json"
    with open(out, "w") as f:
        json.dump({"dataset": "cardio", "quick": QUICK, "rows": rows}, f,
                  indent=2)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
