"""Sensor-stream serving throughput: single engine + multi-tenant fleet.

Single-engine section: compiles the cardio exact TNN (the paper's mid-size
Table-2 design) to a `CircuitProgram` and measures end-to-end engine
throughput — raw readings in, class labels out, including ABC
binarization, bit-packing and decode — at batch sizes {1, 64, 1024}, with
a numpy-backend row at the largest batch anchoring the jitted SWAR
speedup.

Fleet section: a 2-tenant `ClassifierFleet` (cardio + breast_cancer)
replays concurrent held-out streams from 4 producer threads through the
deadline-driven micro-batching scheduler, recording per-tenant and
fleet-wide rows (readings/s, request p50/p99, SLO misses) under
`bench == "serve_fleet"`.

Socket section: the same 2-tenant replay, but every reading crosses the
length-prefixed TCP transport (`serve/server.py` + `serve/client.py`).
`bench == "serve_socket"` rows ride the protocol-v2 batched ingest path
(`SUBMIT_BATCH` frames, 256 readings per frame); the classic one-frame-
per-reading path is kept as `bench == "serve_socket_unary"` so the
batching win stays one diff away.

Workers section (`bench == "serve_workers"`): a 4-tenant fleet (cardio +
breast_cancer on the jitted SWAR backend, redwine + whitewine on numpy)
with `workers=2`, so every dispatch crosses a process boundary into a
spawned backend worker via the shared-memory slab ring.  The feed is
whole 2048-reading `submit_many` frames — the batched ingest path — so
the per-dispatch IPC cost (slab copy + pickle + wakeup) is amortized over
a whole frame, and every label is checked bit-identical against the
offline reference.

Megakernel section (`bench == "serve_megakernel"`): the same 4 tenants,
all pinned to the pallas backend, replayed twice — per-tenant dispatch
(one kernel launch per tenant batch) vs the fused multi-program
megakernel (`megakernel=True`: every due tenant's circuit rides ONE
`fleet_eval_words` launch per scheduler pass).  Labels are bit-checked
against the offline reference both ways, and the megakernel rows record
the fused launch count + the most tenants any single launch carried.

QoS section (`bench == "serve_qos"`): a synthetic overload scenario — a
guaranteed and a best-effort tenant share one deliberately slowed numpy
backend while both are blasted with interleaved singles.  The committed
row must show the best-effort tenant shedding (reason `"qos"`) while the
guaranteed tenant records zero sheds and zero SLO misses: overload lands
on the tenant that opted into degradation, never the one paying for
isolation.

Swarm section (`bench == "serve_swarm"`): the many-clients story.  A TCP
soak opens thousands of short-lived connections (10k full, scaled down
under QUICK) against a sharded `SO_REUSEPORT` server, each handshaking
and pushing one batch frame — connection churn + ingest concurrency, not
single-pipe throughput.  A UDP firehose row blasts fire-and-forget
`SUBMIT_BATCH` datagrams at the connectionless ingest endpoint and
reports the received fraction (best-effort delivery, measured not
assumed).  Writes BENCH_serve.json.

Any row with `n_slo_miss > 0` triggers a loud stderr warning — a
committed artifact should not quietly carry a latency regression.

Run directly to (re)generate the committed artifact:

    PYTHONPATH=src python -m benchmarks.serve_throughput [BENCH_serve.json]
"""
from __future__ import annotations

import asyncio
import json
import struct
import sys
import time

import numpy as np

from benchmarks.common import QUICK, get_trained_tnn
from repro.core.tnn import exact_netlists
from repro.compile.ir import lower_classifier
from repro.compile.program import CircuitProgram
from repro.serve.engine import CircuitServingEngine

BATCH_SIZES = (1, 64, 1024)
FLEET_DATASETS = ("cardio", "breast_cancer")
WORKER_TENANTS = (("cardio", "swar"), ("breast_cancer", "swar"),
                  ("redwine", "np"), ("whitewine", "np"))
WORKER_PROCS = 2            # spawned worker processes per backend
WORKER_FRAME = 2048         # readings per submit_many frame (IPC amortization)
MEGAKERNEL_TENANTS = ("cardio", "breast_cancer", "redwine", "whitewine")
MEGAKERNEL_FRAME = 1024     # readings per frame for the megakernel rows
MEGAKERNEL_DEADLINE_MS = 2000.0   # interpret-mode pallas launches on this
                                  # CPU container take ~1s; the row measures
                                  # fusion economics, not a latency SLO
QOS_DELAY_S = 0.005         # synthetic per-dispatch slowdown (overload)
QOS_BACKLOG = 8             # best_effort_backlog for the overload row
FLEET_DEADLINE_MS = 250.0   # above the full-speed replay's queueing delay
SOCKET_BATCH = 256          # readings per SUBMIT_BATCH frame (v2 path)
SWARM_CONNS = 200 if QUICK else 10_000
SWARM_CONCURRENCY = 128 if QUICK else 1000  # open sockets at once (fd cap)
SWARM_READINGS_PER_CONN = 16
SWARM_DEADLINE_MS = 2000.0  # generous: soak measures churn, not latency
UDP_READINGS = 4096 if QUICK else 65_536


def _stream(x_test: np.ndarray, n: int, seed: int = 0) -> np.ndarray:
    """n readings drawn (with wraparound) from the test distribution."""
    idx = np.random.default_rng(seed).integers(0, x_test.shape[0], size=n)
    return x_test[idx]


def _measure(prog: CircuitProgram, x_test: np.ndarray, batch: int,
             n_readings: int) -> dict:
    engine = CircuitServingEngine(prog, max_batch=batch)
    engine.warmup()
    engine.classify_stream(_stream(x_test, n_readings))
    s = engine.stats.summary()
    return {
        "batch": batch,
        "readings": s["n_readings"],
        "readings_per_s": s["readings_per_s"],
        "p50_ms": s["p50_ms"],
        "p99_ms": s["p99_ms"],
    }


def _fleet_specs_and_streams(n_readings: int):
    from repro.serve import TenantSpec

    specs, streams = [], {}
    for i, dataset in enumerate(FLEET_DATASETS):
        ds, tnn = get_trained_tnn(dataset)
        cc = lower_classifier(tnn, *exact_netlists(tnn))
        name = f"tnn_{dataset}"
        specs.append(TenantSpec(
            name=name, program=CircuitProgram.from_classifier(cc),
            backend="swar", max_batch=256, deadline_ms=FLEET_DEADLINE_MS,
            dataset=dataset))
        streams[name] = _stream(ds.x_test, n_readings, seed=i)
    return specs, streams


def _report_rows(bench: str, report: dict, deadline_ms: float,
                 **extra) -> list[dict]:
    rows = []
    for name, t in report["tenants"].items():
        rows.append({"bench": bench, "tenant": name,
                     "backend": t["backend"],
                     "deadline_ms": deadline_ms,
                     "readings": t["n_readings"],
                     "readings_per_s": t["readings_per_s"],
                     "req_p50_ms": t["req_p50_ms"],
                     "req_p99_ms": t["req_p99_ms"],
                     "n_slo_miss": t["n_slo_miss"],
                     "labels_match_offline": t["labels_match_offline"],
                     **extra})
    f = report["fleet"]
    rows.append({"bench": bench, "tenant": "__fleet__",
                 "backend": "swar", "deadline_ms": deadline_ms,
                 "readings": f["n_readings"],
                 "readings_per_s": f["readings_per_s"],
                 "req_p50_ms": f["req_p50_ms"],
                 "req_p99_ms": f["req_p99_ms"],
                 "n_slo_miss": f["n_slo_miss"],
                 "labels_match_offline": report["labels_match_offline"],
                 **extra})
    return rows


def _warn_slo_misses(rows: list[dict]) -> None:
    """Satellite guard: a committed artifact must not quietly carry SLO
    misses — shout about every row that does."""
    for r in rows:
        if r.get("n_slo_miss", 0):
            print(f"\n{'!' * 72}\n"
                  f"!!! WARNING: {r['bench']} tenant={r['tenant']} recorded "
                  f"{r['n_slo_miss']} SLO misses\n"
                  f"!!! (deadline_ms={r.get('deadline_ms')}) — this "
                  f"artifact carries a latency regression\n"
                  f"{'!' * 72}\n", file=sys.stderr)


def _measure_fleet(n_readings: int) -> list[dict]:
    """2-tenant concurrent replay through the micro-batching scheduler."""
    from repro.serve import ClassifierFleet
    from repro.serve.__main__ import replay_fleet

    specs, streams = _fleet_specs_and_streams(n_readings)
    fleet = ClassifierFleet(specs)
    try:
        report = replay_fleet(fleet, streams, producers=4, timeout=600)
    finally:
        fleet.shutdown(drain=True)
    return _report_rows("serve_fleet", report, FLEET_DEADLINE_MS)


def _frame_replay(fleet, streams: dict, frame: int,
                  preload: bool = False) -> tuple[dict, float]:
    """Feed each tenant whole `(frame, F)` frames through `submit_many`,
    interleaved round-robin across tenants, wait for every handle, and
    check every label bit-identical against the offline reference.
    Returns (report, wall_seconds).

    `preload=True` expects a fleet built with `autostart=False`: every
    frame is queued before the scheduler starts, so the first tick sees
    the whole manifest due at once — the steady-state shape the
    megakernel rows are about (with the scheduler live during the feed,
    frames dispatch one by one as they arrive and a fused launch rarely
    carries more than the tenant that happened to be due)."""
    frames = []
    for name, x in streams.items():
        for f, s in enumerate(range(0, x.shape[0], frame)):
            frames.append((f, name, x[s:s + frame]))
    frames.sort(key=lambda t: t[0])  # round-robin across tenants

    pending = {name: [] for name in streams}
    t0 = time.perf_counter()
    for _, name, rows_ in frames:
        reqs, shed, _ = fleet.submit_many(name, rows_)
        assert shed.size == 0  # no admission limits armed here
        pending[name].extend(reqs)
    if preload:
        fleet.start()
    for reqs in pending.values():
        for r in reqs:
            r.result(timeout=600)
    wall = time.perf_counter() - t0

    report = {"tenants": {}}
    ok_all = True
    for name, reqs in pending.items():
        labels = np.array([r.label for r in reqs], dtype=np.int32)
        t = fleet._tenant(name)
        ref = t.engine.program.predict(streams[name]).astype(np.int32)
        match = bool(np.array_equal(labels, ref))
        ok_all = ok_all and match
        report["tenants"][name] = {
            "backend": t.spec.backend,
            "labels_match_offline": match,
            **t.stats.summary()}
    report["fleet"] = fleet.stats.summary()
    report["labels_match_offline"] = ok_all
    return report, wall


def _measure_workers(n_readings: int) -> list[dict]:
    """4-tenant frame replay with dispatch in spawned worker processes.

    The shared-memory hop must not change a single bit — every label is
    checked against the in-process offline reference."""
    from repro.serve import ClassifierFleet, TenantSpec

    specs, streams = [], {}
    for i, (dataset, backend) in enumerate(WORKER_TENANTS):
        ds, tnn = get_trained_tnn(dataset)
        cc = lower_classifier(tnn, *exact_netlists(tnn))
        name = f"tnn_{dataset}"
        specs.append(TenantSpec(
            name=name,
            program=CircuitProgram.from_classifier(cc, backend=backend),
            backend=backend, max_batch=WORKER_FRAME,
            deadline_ms=FLEET_DEADLINE_MS, dataset=dataset))
        streams[name] = _stream(ds.x_test, n_readings, seed=i)

    fleet = ClassifierFleet(specs, workers=WORKER_PROCS)
    try:
        report, wall = _frame_replay(fleet, streams, WORKER_FRAME)
        total = sum(x.shape[0] for x in streams.values())
    finally:
        fleet.shutdown(drain=True)
    return _report_rows("serve_workers", report, FLEET_DEADLINE_MS,
                        workers=WORKER_PROCS,
                        wall_readings_per_s=round(total / wall, 1))


def _measure_megakernel(n_readings: int) -> list[dict]:
    """serve_megakernel rows: the same 4-tenant pallas fleet replayed twice
    — per-tenant dispatch (one kernel launch per tenant batch) vs the
    fused multi-program megakernel (every due tenant in ONE launch per
    scheduler pass).  Both runs check every label bit-identical against
    the offline reference; the megakernel rows also record how many fused
    launches the tick scheduler actually made and the most tenants any
    single launch carried."""
    from repro.serve import ClassifierFleet, TenantSpec

    rows = []
    for mode in ("per_tenant", "megakernel"):
        specs, streams = [], {}
        for i, dataset in enumerate(MEGAKERNEL_TENANTS):
            ds, tnn = get_trained_tnn(dataset)
            cc = lower_classifier(tnn, *exact_netlists(tnn))
            name = f"tnn_{dataset}"
            specs.append(TenantSpec(
                name=name,
                program=CircuitProgram.from_classifier(cc, backend="pallas"),
                backend="pallas", max_batch=MEGAKERNEL_FRAME,
                deadline_ms=MEGAKERNEL_DEADLINE_MS, dataset=dataset))
            streams[name] = _stream(ds.x_test, n_readings, seed=i)
        fleet = ClassifierFleet(specs, megakernel=(mode == "megakernel"),
                                autostart=False)
        try:
            report, wall = _frame_replay(fleet, streams, MEGAKERNEL_FRAME,
                                         preload=True)
            total = sum(x.shape[0] for x in streams.values())
            extra = {"mode": mode,
                     "wall_readings_per_s": round(total / wall, 1)}
            if mode == "megakernel":
                mk = fleet.stats_summary()["megakernel"]
                extra["megakernel_launches"] = mk["launches"]
                extra["peak_tenants_per_launch"] = \
                    mk["peak_tenants_per_launch"]
        finally:
            fleet.shutdown(drain=True)
        rows.extend(_report_rows("serve_megakernel", report,
                                 MEGAKERNEL_DEADLINE_MS, **extra))
    return rows


class _SlowProgram:
    """Delegating wrapper that makes every predict cost `delay_s` — a
    deterministic stand-in for an overloaded backend."""

    def __init__(self, inner, delay_s: float):
        self._inner, self._delay_s = inner, delay_s

    def predict(self, x: np.ndarray) -> np.ndarray:
        time.sleep(self._delay_s)
        return self._inner.predict(x)

    def __getattr__(self, name: str):
        return getattr(self._inner, name)


def _measure_qos() -> list[dict]:
    """serve_qos rows: guaranteed + best-effort tenants sharing one slowed
    backend under interleaved overload.  The committed artifact must show
    the best-effort tenant shedding while the guaranteed tenant keeps
    zero sheds and zero SLO misses."""
    from repro.serve import ClassifierFleet, TenantSpec
    from repro.serve.fleet import FleetOverloadError

    ds, tnn = get_trained_tnn("cardio")
    cc = lower_classifier(tnn, *exact_netlists(tnn))
    deadline_ms = 20_000.0  # generous: the row measures shedding, not SLO
    specs = [
        TenantSpec(name="gold",
                   program=CircuitProgram.from_classifier(cc, backend="np"),
                   backend="np", max_batch=8, deadline_ms=deadline_ms,
                   qos="guaranteed", dataset="cardio"),
        TenantSpec(name="cheap",
                   program=CircuitProgram.from_classifier(cc, backend="np"),
                   backend="np", max_batch=8, deadline_ms=deadline_ms,
                   max_queue=64, qos="best_effort", dataset="cardio"),
    ]
    fleet = ClassifierFleet(specs, warmup=False, autostart=False,
                            best_effort_backlog=QOS_BACKLOG)
    for name in ("gold", "cheap"):
        for rep in fleet._tenant(name).pool.replicas:
            rep.engine.program = _SlowProgram(rep.engine.program,
                                              QOS_DELAY_S)
    fleet.start()

    n = 256 if QUICK else 1024
    x = _stream(ds.x_test, n, seed=5)
    want = CircuitProgram.from_classifier(
        cc, backend="np").predict(x).astype(np.int32)
    gold_reqs, cheap_admitted, cheap_shed = [], 0, 0
    try:
        for i in range(n):
            gold_reqs.append(fleet.submit("gold", x[i]))
            try:
                fleet.submit("cheap", x[i])
                cheap_admitted += 1
            except FleetOverloadError:
                cheap_shed += 1
        labels = np.array([r.result(timeout=600) for r in gold_reqs],
                          dtype=np.int32)
        summary = fleet.stats_summary()["tenants"]
    finally:
        fleet.shutdown(drain=True)

    rows = []
    for tenant, extra in (
            ("gold", {"labels_match_offline":
                      bool(np.array_equal(labels, want))}),
            ("cheap", {"admitted": cheap_admitted,
                       "shed_at_submit": cheap_shed,
                       "best_effort_backlog": QOS_BACKLOG})):
        t = summary[tenant]
        rows.append({"bench": "serve_qos", "tenant": tenant,
                     "qos": t["qos"], "backend": "np",
                     "deadline_ms": deadline_ms,
                     "readings": t["n_readings"],
                     "n_shed": t["n_shed"],
                     "n_slo_miss": t["n_slo_miss"],
                     "slow_dispatch_s": QOS_DELAY_S, **extra})
    if not (rows[0]["n_shed"] == 0 and rows[0]["n_slo_miss"] == 0
            and rows[1]["n_shed"] > 0):
        print("\n!!! WARNING: serve_qos overload row did not isolate the "
              "guaranteed tenant "
              f"(gold shed={rows[0]['n_shed']} slo={rows[0]['n_slo_miss']},"
              f" cheap shed={rows[1]['n_shed']})", file=sys.stderr)
    return rows


def _measure_socket(bench: str, n_readings: int, batch: int) -> list[dict]:
    """The same 2-tenant replay, every reading over the TCP transport —
    `batch` readings per SUBMIT_BATCH frame (1 = classic unary frames)."""
    from repro.serve import ClassifierFleet
    from repro.serve.__main__ import replay_client
    from repro.serve.client import FleetClient
    from repro.serve.server import FleetServer

    specs, streams = _fleet_specs_and_streams(n_readings)
    fleet = ClassifierFleet(specs)
    server = FleetServer(fleet)
    try:
        host, port = server.start_background()
        with FleetClient(host, port) as client:
            report = replay_client(client, fleet, streams, producers=4,
                                   timeout=600, batch=batch)
    finally:
        server.stop()
        fleet.shutdown(drain=True)
    return _report_rows(bench, report, FLEET_DEADLINE_MS, batch=batch)


async def _swarm_read_frame(reader: asyncio.StreamReader) -> bytes:
    (ln,) = struct.unpack("!I", await reader.readexactly(4))
    return await reader.readexactly(ln)


async def _swarm_soak(host: str, port: int, tenant: str, x: np.ndarray,
                      ref: np.ndarray, n_conns: int,
                      per_conn: int) -> dict:
    """`n_conns` short-lived connections, each handshaking and pushing one
    `per_conn`-reading batch frame, at most SWARM_CONCURRENCY sockets open
    at once (one process holds both ends on loopback — stay under the fd
    cap).  Labels are checked against the offline reference per
    connection, so the soak doubles as a correctness sweep."""
    from repro.serve import protocol as P

    sem = asyncio.Semaphore(SWARM_CONCURRENCY)
    n_bad = 0

    async def one_conn(c: int) -> int:
        nonlocal n_bad
        s = (c * per_conn) % max(1, x.shape[0] - per_conn)
        rows, want = x[s:s + per_conn], ref[s:s + per_conn]
        async with sem:
            reader, writer = await asyncio.open_connection(host, port)
            try:
                writer.write(P.encode_hello(P.PROTOCOL_VERSION))
                msg = P.decode_message(await _swarm_read_frame(reader))
                assert msg.type == P.MSG_WELCOME and msg.version >= 2
                rids = np.arange(1, per_conn + 1, dtype=np.uint64)
                writer.write(P.encode_submit_batch(rids, tenant, rows))
                await writer.drain()
                got = {}
                while len(got) < per_conn:
                    msg = P.decode_message(await _swarm_read_frame(reader))
                    if msg.type == P.MSG_RESULT_BATCH:
                        for rid, lab in zip(msg.req_ids, msg.labels):
                            got[int(rid)] = int(lab)
                    elif msg.type == P.MSG_RESULT:
                        got[msg.req_id] = msg.label
                    else:
                        raise RuntimeError(f"soak conn {c}: unexpected "
                                           f"message type {msg.type}")
                labels = np.array([got[int(r)] for r in rids])
                if not np.array_equal(labels, want):
                    n_bad += 1
                return per_conn
            finally:
                writer.close()
                await writer.wait_closed()

    t0 = time.perf_counter()
    done = await asyncio.gather(*(one_conn(c) for c in range(n_conns)))
    dt = time.perf_counter() - t0
    return {"n_connections": n_conns, "readings": int(sum(done)),
            "readings_per_s": round(sum(done) / dt, 1),
            "conns_per_s": round(n_conns / dt, 1),
            "labels_match_offline": n_bad == 0}


def _measure_swarm() -> list[dict]:
    """serve_swarm rows: the 10k-connection TCP soak against a sharded
    server, then the UDP firehose with its measured received fraction."""
    from repro.serve import ClassifierFleet
    from repro.serve.client import FleetClient, UdpSwarmSender
    from repro.serve.server import FleetServer

    specs, streams = _fleet_specs_and_streams(
        SWARM_READINGS_PER_CONN * 64)
    for s in specs:
        s.deadline_ms = SWARM_DEADLINE_MS
    tenant = specs[0].name
    x = streams[tenant]
    ref = specs[0].program.predict(x).astype(np.int32)

    fleet = ClassifierFleet(specs)
    server = FleetServer(fleet, shards=2, udp_port=0)
    rows = []
    try:
        host, port = server.start_background()
        soak = asyncio.run(_swarm_soak(host, port, tenant, x, ref,
                                       SWARM_CONNS,
                                       SWARM_READINGS_PER_CONN))
        with FleetClient(host, port) as admin:
            slo = admin.stats()["fleet"].get("n_slo_miss", 0)
        rows.append({"bench": "serve_swarm", "tenant": tenant,
                     "transport": "tcp_soak", "backend": "swar",
                     "deadline_ms": SWARM_DEADLINE_MS,
                     "n_slo_miss": int(slo), "shards": 2, **soak})

        with FleetClient(host, port) as admin:
            before = admin.stats()["transport"]["udp"]
            sender = UdpSwarmSender(host, server.udp_address[1])
            t0 = time.perf_counter()
            sent = 0
            for s in range(0, UDP_READINGS, SOCKET_BATCH):
                idx = np.arange(s, min(s + SOCKET_BATCH,
                                       UDP_READINGS)) % x.shape[0]
                sent += sender.send_many(tenant, x[idx])
            send_s = time.perf_counter() - t0
            sender.close()
            deadline, last = time.monotonic() + 60, -1
            while time.monotonic() < deadline:
                udp = admin.stats()["transport"]["udp"]
                got = udp["n_readings"] - before["n_readings"]
                if got >= sent or (got == last and got > 0):
                    break
                last = got
                time.sleep(0.25)
            udp = admin.stats()["transport"]["udp"]
        received = udp["n_readings"] - before["n_readings"]
        rows.append({"bench": "serve_swarm", "tenant": tenant,
                     "transport": "udp_firehose", "backend": "swar",
                     "deadline_ms": SWARM_DEADLINE_MS,
                     "readings_sent": int(sent),
                     "readings_received": int(received),
                     "received_frac": round(received / max(1, sent), 4),
                     "send_rate_per_s": round(sent / max(send_s, 1e-9), 1),
                     "n_errors": udp["n_errors"] - before["n_errors"]})
    finally:
        server.stop()
        fleet.shutdown(drain=True)
    return rows


def run() -> list[dict]:
    ds, tnn = get_trained_tnn("cardio")
    cc = lower_classifier(tnn, *exact_netlists(tnn))
    prog = CircuitProgram.from_classifier(cc)

    rows = []
    for batch in BATCH_SIZES:
        n = (max(256, 4 * batch) if QUICK else max(4096, 64 * batch))
        row = {"bench": "serve", "backend": "jax",
               "gates": cc.ir.n_gates, "depth": cc.ir.depth,
               **_measure(prog, ds.x_test, batch, n)}
        rows.append(row)

    prog_np = CircuitProgram.from_classifier(cc, backend="np")
    n = 2048 if QUICK else 16384
    rows.append({"bench": "serve", "backend": "np",
                 "gates": cc.ir.n_gates, "depth": cc.ir.depth,
                 **_measure(prog_np, ds.x_test, 1024, n)})

    n_fleet = 2048 if QUICK else 16384
    rows.extend(_measure_fleet(n_fleet))
    rows.extend(_measure_workers(n_fleet))
    rows.extend(_measure_megakernel(n_fleet))
    rows.extend(_measure_qos())
    rows.extend(_measure_socket("serve_socket", n_fleet, SOCKET_BATCH))
    rows.extend(_measure_socket("serve_socket_unary",
                                512 if QUICK else 4096, 1))
    rows.extend(_measure_swarm())
    _warn_slo_misses(rows)

    out = sys.argv[1] if (__name__ == "__main__" and len(sys.argv) > 1) \
        else "BENCH_serve.json"
    with open(out, "w") as f:
        json.dump({"dataset": "cardio", "quick": QUICK, "rows": rows}, f,
                  indent=2)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
