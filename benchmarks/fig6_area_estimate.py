"""Fig. 6: Phase-2 area estimate (sum of PC areas) vs modeled synthesis
(composed netlist incl. comparator).  Validated claim: good correlation,
with systematic underestimation for small PCCs (comparator ignored)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import tnn_libraries


def run(dataset: str = "cardio") -> list[dict]:
    _, _, pcc_lib, _ = tnn_libraries(dataset)
    est, synth = [], []
    rows = []
    for size in pcc_lib.sizes():
        for e in pcc_lib.get(size[0], size[1]):
            est.append(e.est_area)
            synth.append(e.synth_area)
            rows.append({"bench": "fig6", "size": f"{size[0]}x{size[1]}",
                         "est_area_mm2": round(e.est_area, 3),
                         "synth_area_mm2": round(e.synth_area, 3)})
    est, synth = np.array(est), np.array(synth)
    corr = float(np.corrcoef(est, synth)[0, 1]) if len(est) > 2 else 1.0
    rows.append({"bench": "fig6_summary", "dataset": dataset,
                 "n_points": len(est), "pearson_r": round(corr, 4),
                 "underestimates": int((est < synth).sum()),
                 "mean_ratio": round(float((synth / np.maximum(est, 1e-9)).mean()), 3)})
    return rows
