"""Fig. 5: PCC Pareto trade-off + distance-error histograms.

Validated claims: (a) Pareto-optimal approximate PCCs trade eps_mde for
area monotonically; (b) moderate settings keep most operations error-free
(paper: 95.57% error-free at 12.6% area reduction for the 45x39 neuron).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK, tnn_libraries


def run(dataset: str = "arrhythmia") -> list[dict]:
    ds, tnn, pcc_lib, _ = tnn_libraries(dataset)
    rows = []
    for (npos, nneg) in pcc_lib.sizes():
        entries = pcc_lib.get(npos, nneg)
        exact_est = entries[0].est_area
        for rank, e in enumerate(entries):
            rows.append({
                "bench": "fig5", "dataset": dataset,
                "n_pos": npos, "n_neg": nneg, "rank": rank,
                "mde": round(e.mde, 4), "wcde": e.wcde,
                "correct_frac": round(e.correct_frac, 4),
                "rel_est_area": round(e.est_area / max(exact_est, 1e-9), 3),
                "synth_area_mm2": round(e.synth_area, 3),
            })
    # distance histogram for the largest PCC's mid-Pareto entry (Fig. 5b)
    biggest = max(pcc_lib.sizes(), key=lambda s: s[0] + s[1])
    entries = pcc_lib.get(*biggest)
    if len(entries) > 1:
        from repro.core.pcc import sample_pair_domain
        e = entries[min(1, len(entries) - 1)]
        S = 20000 if QUICK else 200000
        pp, pn, x, z = sample_pair_domain(e.n_pos, e.n_neg, S, seed=0)
        rel = x >= z
        rel_a = e.pc_pos.eval_uint(pp)[:S] >= e.pc_neg.eval_uint(pn)[:S]
        D = np.where(rel == rel_a, 0, x - z)
        hist, edges = np.histogram(D, bins=np.arange(-8.5, 9.5))
        rows.append({"bench": "fig5_hist", "dataset": dataset,
                     "n_pos": e.n_pos, "n_neg": e.n_neg,
                     "bins": edges[:-1].astype(int).tolist(),
                     "counts": hist.tolist()})
    return rows
