"""Beyond-paper extension: ABC threshold variation robustness (Sec. 3.2.1).

The paper notes that printed-process variations perturb the R1/R2 divider
ratio, shifting each ABC's threshold V_q, and defers variation-aware
training to future work.  This benchmark quantifies the exposure the paper
left open, and evaluates the mitigation it proposes:

  * Monte-Carlo perturb the per-feature thresholds (relative sigma on the
    divider ratio) and measure exact-TNN accuracy distributions;
  * variation-aware QAT: re-train with threshold noise *injected during
    training* (fresh binarization noise per epoch) and compare degradation.

Output rows: sigma, mean/p5 accuracy, clean accuracy, for both vanilla and
variation-aware training.
"""
from __future__ import annotations

import numpy as np

from repro.core import tnn as T
from repro.core.ternary import abc_fit_thresholds
from repro.data.tabular import make_dataset
from benchmarks.common import QUICK


def _acc_under_variation(tnn, ds, sigma: float, n_mc: int, rng) -> np.ndarray:
    accs = []
    for _ in range(n_mc):
        thr = tnn.thresholds * (1.0 + rng.normal(0, sigma,
                                                 tnn.thresholds.shape))
        xb = (ds.x_test > thr[None, :]).astype(np.int64)
        accs.append(float((T.predict_exact(tnn, xb) == ds.y_test).mean()))
    return np.array(accs)


def _train_variation_aware(ds, n_hidden: int, sigma: float, seed: int = 0):
    """QAT with threshold-noise injection: each epoch re-binarizes the
    inputs under a fresh V_q perturbation (DESIGN.md: the 'variation-aware
    training' the paper proposes but does not implement)."""
    import jax
    import jax.numpy as jnp
    from repro.optim import adamw
    from repro.optim.adamw import AdamWConfig
    from repro.core.tnn import (_loss_fn, balance_zero_counts, predict_exact,
                                TrainedTNN)
    from repro.core.ternary import ternarize, TERNARY_THRESHOLD

    thresholds = abc_fit_thresholds(ds.x_train)
    F, H, C = ds.spec.n_features, n_hidden, ds.spec.n_classes
    rng = np.random.default_rng(seed)
    params = {"w1": jnp.asarray(rng.normal(0, 0.7, (F, H)), jnp.float32),
              "w2": jnp.asarray(rng.normal(0, 0.7, (H, C)), jnp.float32)}
    state = adamw.init(params)
    ocfg = AdamWConfig(lr=5e-3, grad_clip=1.0)

    @jax.jit
    def step(params, state, xb, y):
        loss, grads = jax.value_and_grad(_loss_fn)(
            params, xb, y, TERNARY_THRESHOLD, H)
        params, state = adamw.apply_updates(params, grads, state, ocfg)
        return params, state, loss

    y_j = jnp.asarray(ds.y_train.astype(np.int32))
    n = ds.x_train.shape[0]
    for epoch in range(12 if QUICK else 18):
        thr = thresholds * (1.0 + rng.normal(0, sigma, thresholds.shape))
        xb = jnp.asarray((ds.x_train > thr[None, :]).astype(np.float32))
        perm = rng.permutation(n)
        for s in range(0, n, 64):
            idx = perm[s:s + 64]
            params, state, _ = step(params, state, xb[idx], y_j[idx])

    w1t = np.asarray(ternarize(params["w1"], TERNARY_THRESHOLD)).astype(np.int8)
    w2t = balance_zero_counts(np.asarray(params["w2"]), TERNARY_THRESHOLD)
    tnn = TrainedTNN(w1t=w1t, w2t=w2t, thresholds=thresholds,
                     train_acc=0.0, test_acc=0.0, name=ds.name + "-va")
    xb_te = (ds.x_test > thresholds[None, :]).astype(np.int64)
    tnn.test_acc = float((predict_exact(tnn, xb_te) == ds.y_test).mean())
    return tnn


def run(datasets=None) -> list[dict]:
    datasets = datasets or (["cardio"] if QUICK else ["cardio", "breast_cancer",
                                                      "redwine"])
    sigmas = [0.02, 0.05, 0.10]
    n_mc = 20 if QUICK else 100
    rng = np.random.default_rng(0)
    rows = []
    for name in datasets:
        ds = make_dataset(name)
        vanilla = T.train_tnn(ds, T.TNNTrainConfig(
            n_hidden=ds.spec.topology[1], epochs=12 if QUICK else 18,
            lr=1e-2, seed=0))
        for sigma in sigmas:
            aware = _train_variation_aware(ds, ds.spec.topology[1], sigma)
            a_v = _acc_under_variation(vanilla, ds, sigma, n_mc, rng)
            a_a = _acc_under_variation(aware, ds, sigma, n_mc, rng)
            rows.append({
                "bench": "variation", "dataset": name, "sigma": sigma,
                "clean_acc": round(vanilla.test_acc, 3),
                "vanilla_mean": round(float(a_v.mean()), 3),
                "vanilla_p5": round(float(np.percentile(a_v, 5)), 3),
                "aware_clean": round(aware.test_acc, 3),
                "aware_mean": round(float(a_a.mean()), 3),
                "aware_p5": round(float(np.percentile(a_a, 5)), 3),
                "aware_helps": bool(a_a.mean() >= a_v.mean() - 1e-9),
            })
    return rows
