"""Autopilot loop economics: mirror tax + time-to-first-promotion.

Overhead section (`bench == "autopilot_overhead"`): one breast_cancer
exact-TNN tenant on the swar backend replays a held-out stream through
`submit_many` twice — once bare, once with a byte-identical shadow
deployed so every admitted request is mirrored — and reports incumbent
readings/s and request p50/p99 for both, plus a `mirror_tax` row with the
throughput/latency ratios.  This is the number an operator needs before
leaving a shadow attached to a production tenant: what mirroring costs
the *primary* path (the shadow's own work is off the incumbent's books
by construction; the tax is queue/lock contention and the mirror copy).

Promotion section (`bench == "autopilot_promotion"`): one full controller
round against a live fleet — stage the candidate bundle, shadow-deploy,
mirror labeled pairs until the policy floor, decide, atomic manifest
swap — timed from `Autopilot.run()` entry to the journaled `promoted`
event, with the per-stage breakdown recovered from the decision
journal's own timestamps.  Writes BENCH_autopilot.json.

Run directly to (re)generate the committed artifact:

    PYTHONPATH=src python -m benchmarks.autopilot_loop [BENCH_autopilot.json]
"""
from __future__ import annotations

import json
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import QUICK, get_trained_tnn
from repro.autopilot import (Autopilot, AutopilotConfig, Candidate,
                             DecisionJournal, PromotionPolicy,
                             ScriptedSource, dataset_traffic)
from repro.compile import write_artifacts
from repro.compile.ir import lower_classifier
from repro.core.tnn import exact_netlists
from repro.serve import ClassifierFleet

DATASET = "breast_cancer"
N_READINGS = 4096 if QUICK else 65_536
REPLAY_BATCH = 64
DEADLINE_MS = 1000.0        # generous: the bench measures tax, not misses
MIRROR_PAIRS = 96 if QUICK else 512


def _emit_incumbent(out: Path):
    ds, tnn = get_trained_tnn(DATASET)
    cc = lower_classifier(tnn, *exact_netlists(tnn))
    write_artifacts(cc, out, base=f"tnn_{DATASET}", dataset=DATASET)
    return ds, cc


def _replay(fleet: ClassifierFleet, name: str, x_test: np.ndarray,
            n: int) -> dict:
    idx = np.random.default_rng(0).integers(0, x_test.shape[0], size=n)
    stream = x_test[idx]
    t0 = time.perf_counter()
    reqs = []
    for lo in range(0, n, REPLAY_BATCH):
        batch, _, _ = fleet.submit_many(name, stream[lo:lo + REPLAY_BATCH])
        reqs.extend(batch)
    fleet.flush()
    for r in reqs:
        r.result(30.0)
    elapsed = time.perf_counter() - t0
    t = fleet.stats_summary()["tenants"][name]
    return {"readings": n, "readings_per_s": n / elapsed,
            "req_p50_ms": t["req_p50_ms"], "req_p99_ms": t["req_p99_ms"],
            "n_slo_miss": t["n_slo_miss"]}


def _overhead_rows() -> list[dict]:
    from repro.compile.program import CircuitProgram
    from repro.serve import TenantSpec

    rows = []
    with tempfile.TemporaryDirectory() as td:
        ds, cc = _emit_incumbent(Path(td))
        name = f"tnn_{DATASET}"
        kw = dict(backends="swar", deadline_ms=DEADLINE_MS)
        with ClassifierFleet.from_emit_dir(td, **kw) as fleet:
            bare = _replay(fleet, name, ds.x_test, N_READINGS)
        with ClassifierFleet.from_emit_dir(td, **kw) as fleet:
            comp = fleet.deploy_shadow(TenantSpec(
                name=f"{name}!shadow", backend="swar",
                program=CircuitProgram.from_classifier(cc, backend="swar"),
                deadline_ms=DEADLINE_MS), name)
            mirrored = _replay(fleet, name, ds.x_test, N_READINGS)
            s = fleet.retire_shadow(name)
        assert s["n_primary_errors"] == 0 and s["n_shadow_errors"] == 0
        rows.append({"bench": "autopilot_overhead", "mode": "bare",
                     "backend": "swar", **bare})
        rows.append({"bench": "autopilot_overhead", "mode": "mirrored",
                     "backend": "swar", **mirrored,
                     "n_mirrored": s["n_mirrored"],
                     "n_dropped": s["n_dropped"],
                     "agreement": s["agreement"]})
        rows.append({"bench": "autopilot_overhead", "mode": "mirror_tax",
                     "throughput_ratio":
                         mirrored["readings_per_s"] / bare["readings_per_s"],
                     "p50_ratio":
                         mirrored["req_p50_ms"] / max(bare["req_p50_ms"],
                                                      1e-9)})
    return rows


def _promotion_rows() -> list[dict]:
    rows = []
    with tempfile.TemporaryDirectory() as td:
        out = Path(td)
        _, cc = _emit_incumbent(out)
        name = f"tnn_{DATASET}"
        # A byte-identical candidate: equal accuracy on mirrored truth, so
        # the policy promotes — the bench times the machinery (staging,
        # shadow warmup, mirrored verdict, manifest swap), not the search.
        source = ScriptedSource([Candidate(
            cc=cc, objectives=[0.0, 0.0], provenance={"bench": True},
            dataset=DATASET)])
        journal = DecisionJournal(out / "autopilot_journal.jsonl")
        cfg = AutopilotConfig(
            tenant=name, rounds=1, mirror_pairs=MIRROR_PAIRS,
            policy=PromotionPolicy(min_pairs=min(64, MIRROR_PAIRS),
                                   min_truth=32))
        with ClassifierFleet.from_emit_dir(
                out, backends="swar", deadline_ms=DEADLINE_MS) as fleet:
            t0 = time.perf_counter()
            outcomes = Autopilot(fleet, source,
                                 dataset_traffic(DATASET, batch=32),
                                 journal, cfg).run()
            elapsed = time.perf_counter() - t0
            gen = fleet.stats_summary()["manifest_generation"]
        assert outcomes[0]["event"] == "promoted", outcomes
        ev = {e["event"]: e["t"] for e in journal.replay()}
        rows.append({
            "bench": "autopilot_promotion",
            "mirror_pairs": MIRROR_PAIRS,
            "time_to_first_promotion_s": elapsed,
            "shadow_deploy_s": ev["shadow_deployed"] - ev["candidate"],
            "shadow_verdict_s": ev["verdict"] - ev["shadow_deployed"],
            "execute_s": ev["promoted"] - ev["decision"],
            "manifest_generation": gen,
        })
    return rows


def run() -> list[dict]:
    return _overhead_rows() + _promotion_rows()


def main(out_path: str = "BENCH_autopilot.json") -> None:
    rows = run()
    for r in rows:
        print(json.dumps(r))
    with open(out_path, "w") as f:
        json.dump({"dataset": DATASET, "quick": QUICK, "rows": rows}, f,
                  indent=2)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_autopilot.json")
