"""Stdlib-only line-coverage measurement for the `repro` package.

The CI coverage gate (`pytest --cov=repro --cov-fail-under=N`) needs a
measured baseline, but this container has no coverage/pytest-cov wheel —
so this tool reproduces coverage.py's line mode with `sys.settrace`:

  * executed lines  — a trace function that instruments only files under
    src/repro (every other frame returns None, paying call-event overhead
    only);
  * executable lines — the union of line numbers in each module's compiled
    code objects (recursively through co_consts), which is exactly the set
    coverage.py derives before excluding pragmas.

Usage:  PYTHONPATH=src python tools/coverage_baseline.py [pytest args...]

Prints per-file and total percentages.  Expect the total to land within a
couple points of pytest-cov (this tool knows no `# pragma: no cover`), so
set the CI floor a safety margin below the number printed here.
"""
from __future__ import annotations

import dis
import os
import sys
import threading
from collections import defaultdict
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
PKG = SRC / "repro"

_executed: dict[str, set[int]] = defaultdict(set)
_prefix = str(PKG) + os.sep


def _tracer(frame, event, arg):
    # Never let an exception escape: CPython silently *disables* tracing
    # for the whole thread if the trace function raises, and the deep
    # recursion in jax tracing tests can push even these few operations
    # over the recursion limit (RecursionError here used to kill coverage
    # of every test after test_models_smoke).
    try:
        fname = frame.f_code.co_filename
        if not fname.startswith(_prefix):
            return None
        if event == "line":
            _executed[fname].add(frame.f_lineno)
        return _tracer
    except Exception:
        return None


class _RearmTracing:
    """Pytest plugin: re-install the tracer if anything knocked it out."""

    def pytest_runtest_teardown(self, item):
        if sys.gettrace() is not _tracer:
            sys.settrace(_tracer)


def _executable_lines(path: Path) -> set[int]:
    try:
        code = compile(path.read_text(), str(path), "exec")
    except SyntaxError:
        return set()
    lines: set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for _, ln in dis.findlinestarts(co) if ln)
        stack.extend(c for c in co.co_consts if hasattr(c, "co_code"))
    return lines


def main(argv: list[str]) -> int:
    # running as a script puts tools/ (not the repo root) first on
    # sys.path, so `from tests.conftest import ...` failed to resolve and
    # pytest aborted the whole run at collection — silently measuring
    # import-time coverage only.  Match `python -m pytest`, which always
    # has the invocation directory importable.
    root = str(SRC.parent)
    if root not in sys.path:
        sys.path.insert(0, root)
    import pytest

    # threading.settrace covers worker/producer threads (the serve fleet's
    # dispatch loops run entirely off the main thread); sys.settrace alone
    # would blind the measurement to the whole concurrency tier
    threading.settrace(_tracer)
    sys.settrace(_tracer)
    try:
        rc = pytest.main(argv or ["-q", "-p", "no:cacheprovider"],
                         plugins=[_RearmTracing()])
    finally:
        sys.settrace(None)
        threading.settrace(None)  # type: ignore[arg-type]

    total_exec, total_hit = 0, 0
    rows = []
    for path in sorted(PKG.rglob("*.py")):
        executable = _executable_lines(path)
        if not executable:
            continue
        hit = len(executable & _executed.get(str(path), set()))
        rows.append((str(path.relative_to(SRC)), hit, len(executable)))
        total_exec += len(executable)
        total_hit += hit
    for name, hit, n in rows:
        print(f"{name:55s} {hit:5d}/{n:<5d} {100.0 * hit / n:5.1f}%")
    pct = 100.0 * total_hit / max(total_exec, 1)
    print(f"{'TOTAL':55s} {total_hit:5d}/{total_exec:<5d} {pct:5.1f}%")
    return int(rc)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
