"""Stdlib-only line-coverage measurement for the `repro` package.

The CI coverage gate (`pytest --cov=repro --cov-fail-under=N`) needs a
measured baseline, but this container has no coverage/pytest-cov wheel —
so this tool reproduces coverage.py's line mode with `sys.settrace`:

  * executed lines  — a trace function that instruments only files under
    src/repro (every other frame returns None, paying call-event overhead
    only);
  * executable lines — the union of line numbers in each module's compiled
    code objects (recursively through co_consts), which is exactly the set
    coverage.py derives before excluding pragmas.

Usage:  PYTHONPATH=src python tools/coverage_baseline.py [pytest args...]

Prints per-file and total percentages.  Expect the total to land within a
couple points of pytest-cov (this tool knows no `# pragma: no cover`), so
set the CI floor a safety margin below the number printed here.
"""
from __future__ import annotations

import dis
import os
import sys
from collections import defaultdict
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
PKG = SRC / "repro"

_executed: dict[str, set[int]] = defaultdict(set)
_prefix = str(PKG) + os.sep


def _tracer(frame, event, arg):
    fname = frame.f_code.co_filename
    if not fname.startswith(_prefix):
        return None
    if event == "line":
        _executed[fname].add(frame.f_lineno)
    return _tracer


def _executable_lines(path: Path) -> set[int]:
    try:
        code = compile(path.read_text(), str(path), "exec")
    except SyntaxError:
        return set()
    lines: set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for _, ln in dis.findlinestarts(co) if ln)
        stack.extend(c for c in co.co_consts if hasattr(c, "co_code"))
    return lines


def main(argv: list[str]) -> int:
    import pytest

    sys.settrace(_tracer)
    try:
        rc = pytest.main(argv or ["-q", "-p", "no:cacheprovider"])
    finally:
        sys.settrace(None)

    total_exec, total_hit = 0, 0
    rows = []
    for path in sorted(PKG.rglob("*.py")):
        executable = _executable_lines(path)
        if not executable:
            continue
        hit = len(executable & _executed.get(str(path), set()))
        rows.append((str(path.relative_to(SRC)), hit, len(executable)))
        total_exec += len(executable)
        total_hit += hit
    for name, hit, n in rows:
        print(f"{name:55s} {hit:5d}/{n:<5d} {100.0 * hit / n:5.1f}%")
    pct = 100.0 * total_hit / max(total_exec, 1)
    print(f"{'TOTAL':55s} {total_hit:5d}/{total_exec:<5d} {pct:5.1f}%")
    return int(rc)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
