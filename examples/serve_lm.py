"""Batched serving example: prefill + greedy decode over request buckets.

Run:  PYTHONPATH=src python examples/serve_lm.py
(thin wrapper over `python -m repro.launch.serve --arch llama3.2-1b --reduced`)
"""
import sys

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    argv = ["--arch", "llama3.2-1b", "--requests", "8", "--max-new", "12"]
    argv += sys.argv[1:]
    sys.argv = [sys.argv[0]] + argv
    serve_main()
