"""The autopilot, end to end: evolve -> shadow-verify -> promote, live.

Seeds a serving directory with a quick exact-TNN tenant for
breast_cancer, stands it up as a live `ClassifierFleet`, and then lets
`repro.autopilot` run three rollout rounds against mirrored traffic:

  1. **rollback drill** — round 0's candidate is deliberately sabotaged
     (`sabotage_classifier` flips the label LSB on every input), so the
     shadow disagrees with the incumbent on all mirrored pairs and the
     controller auto-rolls-back.  The incumbent's stats and error log
     never notice.
  2. **real promotion** — round 1 ships the evolution campaign's best
     Pareto winner; the shadow's accuracy on live labeled traffic meets
     the incumbent's, and one atomic manifest write (generation bump +
     `sync_manifest`) swaps it into the serving slot with queued requests
     intact.
  3. **drift** — round 2 bootstrap-resamples 20% of the campaign's sample
     plane first ("the sensor stream moved"), then repeats the loop.

Every step lands in the decision journal, so re-running this script on
the same out_dir resumes instead of redeciding.  The same loop is a CLI:

    PYTHONPATH=src python -m repro.autopilot run --emit-dir artifacts \
        --tenant tnn_breast_cancer --dataset breast_cancer --rounds 2

Run:  PYTHONPATH=src python examples/autopilot_loop.py [out_dir]
"""
import sys
from pathlib import Path

import numpy as np

from repro.autopilot import (Autopilot, AutopilotConfig, CampaignSource,
                             DecisionJournal, PromotionPolicy,
                             dataset_traffic)
from repro.compile import write_artifacts
from repro.core import tnn as T
from repro.data.tabular import make_dataset
from repro.evolve.campaign import Campaign
from repro.evolve.config import CampaignConfig
from repro.evolve.problems import attach_tnn_drift, build_tnn_problem
from repro.serve import ClassifierFleet

DATASET = "breast_cancer"


def seed_incumbent(out: Path) -> None:
    """Emit a quick exact-TNN tenant as the fleet's starting incumbent."""
    from repro.compile import lower_classifier

    ds = make_dataset(DATASET)
    tnn = T.train_tnn(ds, T.TNNTrainConfig(
        n_hidden=ds.spec.topology[1], epochs=6, lr=1e-2))
    cc = lower_classifier(tnn, *T.exact_netlists(tnn))
    paths = write_artifacts(cc, out, base=f"tnn_{DATASET}", dataset=DATASET)
    print(f"incumbent emitted (acc={tnn.test_acc:.3f}) -> "
          f"{paths['manifest']}")


def main(out_dir: str = "artifacts_autopilot") -> None:
    out = Path(out_dir)
    if not (out / "fleet.json").exists():
        seed_incumbent(out)

    problem = build_tnn_problem(DATASET, epochs=6, cgp_points=2,
                                cgp_iters=120, pcc_samples=4000)
    attach_tnn_drift(problem, rate=0.2)          # rounds re-sample 20%
    campaign = Campaign(problem.domains, problem.objective,
                        CampaignConfig(n_islands=2, pop_size=12, n_epochs=3,
                                       gens_per_epoch=2),
                        checkpoint_dir=str(out / "autopilot_ckpt"),
                        seed_population=problem.seed_population,
                        name=problem.name)
    source = CampaignSource(problem, campaign, require_improvement=False)

    cfg = AutopilotConfig(
        tenant=f"tnn_{DATASET}", rounds=3, mirror_pairs=64,
        policy=PromotionPolicy(min_pairs=48, min_truth=32),
        sabotage_rounds=frozenset({0}))          # round 0: rollback drill
    with ClassifierFleet.from_emit_dir(out, backends="np") as fleet:
        pilot = Autopilot(
            fleet, source, dataset_traffic(DATASET, batch=32),
            DecisionJournal(out / "autopilot_journal.jsonl"), cfg,
            on_event=lambda ev: print(
                f"  [round {ev.get('round', '-')}] {ev['event']}"
                + (f" -> {ev['action']}: {ev['reason']}"
                   if ev["event"] == "decision" else "")))
        outcomes = pilot.run()
        stats = fleet.stats_summary()

    print(f"\noutcomes: {[o['event'] for o in outcomes]}")
    print(f"manifest generation: {stats['manifest_generation']}")
    alpha = stats["tenants"][f"tnn_{DATASET}"]
    print(f"live tenant sha256: {alpha['sha256'][:12]}…  "
          f"({alpha['n_requests']} requests served, "
          f"{alpha['n_slo_miss']} SLO misses)")
    assert outcomes[0]["event"] == "rolled_back"     # the drill rolled back
    print("journal:", out / "autopilot_journal.jsonl")


if __name__ == "__main__":
    main(*sys.argv[1:2])
