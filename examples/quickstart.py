"""Quickstart: the paper's pipeline in ~60 lines.

1. Train a bespoke ternary NN (ABC-binarized inputs, ternary weights).
2. Verify the QAT forward == the gate-level circuit, exactly.
3. Cost the design on the EGFET printed technology, ADC vs ABC interface.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import tnn as T
from repro.core.ternary import abc_binarize
from repro.data.tabular import make_dataset
from repro.hw.egfet import SENSOR_POWER_MW, power_source


def main() -> None:
    ds = make_dataset("breast_cancer")
    print(f"dataset: {ds.name}  {ds.x_train.shape[1]} features, "
          f"{ds.spec.n_classes} classes")

    tnn = T.train_tnn(ds, T.TNNTrainConfig(n_hidden=10, epochs=12, lr=5e-3))
    print(f"exact TNN accuracy: train={tnn.train_acc:.3f} "
          f"test={tnn.test_acc:.3f}")
    print(f"hidden popcount-compare sizes: {tnn.hidden_sizes()}")

    # circuit-accurate check: gate-level netlists == integer forward
    xb = np.asarray(abc_binarize(ds.x_test, tnn.thresholds))
    hidden_nls, out_nls = T.exact_netlists(tnn)
    pred_circuit = T.predict_with_circuits(tnn, xb, hidden_nls, out_nls)
    pred_int = T.predict_exact(tnn, xb)
    assert (pred_circuit == pred_int).all()
    print("circuit-accurate inference matches training forward: OK")

    for iface in (None, "abc", "adc4"):
        c = T.tnn_hw_cost(tnn, hidden_nls, out_nls, interface=iface)
        src = power_source(c.power_mw + SENSOR_POWER_MW)
        print(f"  interface={iface or 'none':5s}: {c.area_cm2:7.3f} cm^2  "
              f"{c.power_mw:7.3f} mW  -> {src}")


if __name__ == "__main__":
    main()
