"""End-to-end LM training driver: ~100M-param model, a few hundred steps.

Trains the `lm100m` preset (8L/512d llama-style) on the deterministic
synthetic token stream, with checkpointing + resume; optionally with the
paper's ternary quantization (--quant ternary) to compare loss curves.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--quant ternary]
(thin wrapper over `python -m repro.launch.train --preset lm100m`)
"""
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    argv = ["--preset", "lm100m", "--steps", "300", "--batch", "8",
            "--seq", "256", "--ckpt-dir", "/tmp/repro_lm100m"]
    argv += sys.argv[1:]
    sys.argv = [sys.argv[0]] + argv
    train_main()
