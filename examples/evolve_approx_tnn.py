"""The paper's full three-phase evolutionary approximation flow (Fig. 3),
followed by the deployment path the evolved winner actually ships through.

Phase 1 — CGP evolves approximate popcount circuits per size.
Phase 2 — Pareto-optimal popcount-compare combinations (distance metric D).
Phase 3 — NSGA-II assigns approximate units per neuron: area vs accuracy.
          With --campaign the single NSGA-II run becomes a resumable
          island-model campaign (repro.evolve): independent islands with
          ring migration of Pareto elites, checkpointed every epoch.
Phase 4 — compile: the chosen Pareto design is lowered to one levelized
          gate IR, emitted as structural Verilog + EGFET report
          (artifacts/), and served as a batched sensor stream through the
          jitted SWAR `CircuitProgram`.

Phases 1 and 2 run population-parallel: every generation's lambda CGP
children are scored in one batched `NetlistPopulation` pass, the tau
schedule's independent runs share a thread pool, and the PCC library
evaluates each candidate circuit once over a shared sample domain.

Run:  PYTHONPATH=src python examples/evolve_approx_tnn.py [dataset]
      PYTHONPATH=src python examples/evolve_approx_tnn.py cardio \
          --campaign [--islands 4] [--ckpt-dir runs/cardio]
"""
import time

import numpy as np

from repro.core import tnn as T
from repro.core.cgp import evolve_pc_library
from repro.core.nsga2 import NSGA2Config
from repro.core.pcc import build_pcc_library, pc_pareto
from repro.core.ternary import abc_binarize
from repro.data.tabular import make_dataset
from repro.compile import CircuitProgram, egfet_report, lower_classifier, \
    write_artifacts
from repro.serve.engine import CircuitServingEngine


def main(dataset: str = "cardio", campaign: bool = False, islands: int = 4,
         ckpt_dir: str | None = None) -> None:
    ds = make_dataset(dataset)
    tnn = T.train_tnn(ds, T.TNNTrainConfig(
        n_hidden=ds.spec.topology[1], epochs=12, lr=1e-2))
    print(f"[exact] acc={tnn.test_acc:.3f} sizes={tnn.hidden_sizes()}")

    # Phase 1: approximate popcount libraries for every size in the TNN
    sizes, pcc_sizes = set(), []
    for (p, n) in tnn.hidden_sizes():
        if p >= 1 and n >= 1:
            sizes.update([p, n])
            pcc_sizes.append((p, n))
    sizes.add(max(tnn.out_nnz, 1))
    pc_libs = {}
    t1 = time.perf_counter()
    for n in sorted(sizes):
        pc_libs[n] = evolve_pc_library(n, n_points=3, max_iters=500)
        print(f"[phase1] pc{n}: {len(pc_libs[n])} circuits "
              f"(areas {[round(c.cost().area_mm2, 2) for c in pc_libs[n]]})")
    print(f"[phase1] evolved {sum(map(len, pc_libs.values()))} circuits over "
          f"{len(sizes)} sizes in {time.perf_counter() - t1:.1f}s "
          "(population-parallel fitness, threaded tau schedule)")

    # Phase 2: Pareto-optimal PCC combinations under the distance metric
    pcc_lib = build_pcc_library(sorted(set(pcc_sizes)), pc_libs,
                                n_samples=30000)
    print(f"[phase2] PCC library: {len(pcc_lib)} Pareto entries over "
          f"{len(pcc_lib.sizes())} sizes")
    pc_out = pc_pareto(pc_libs[max(tnn.out_nnz, 1)])

    # Phase 3: NSGA-II integration
    xb_tr = np.asarray(abc_binarize(ds.x_train, tnn.thresholds))
    xb_te = np.asarray(abc_binarize(ds.x_test, tnn.thresholds))
    prob = T.TNNApproxProblem(tnn=tnn, pcc_lib=pcc_lib, pc_out_lib=pc_out,
                              xbin=xb_tr, y=ds.y_train)
    if campaign:
        from repro.evolve import Campaign, CampaignConfig
        seed_pop = np.zeros((1, prob.n_genes), dtype=np.int64)
        cfg = CampaignConfig(n_islands=islands, pop_size=24, n_epochs=8,
                             gens_per_epoch=5, migrate_k=2, seed=0)
        camp = Campaign(prob.domains(), prob.objective, cfg,
                        checkpoint_dir=ckpt_dir, seed_population=seed_pop,
                        name=f"tnn_{dataset}")
        cres = camp.run()
        if cres.resumed_from is not None:
            print(f"[phase3] resumed campaign from epoch "
                  f"{cres.resumed_from} checkpoint")
        pareto_x, pareto_f = cres.archive_x, cres.archive_f
        print(f"[phase3] island campaign: {islands} islands x "
              f"{cfg.total_generations} gens, archive {len(pareto_x)}")
    else:
        res = prob.optimize(NSGA2Config(pop_size=24, n_generations=40,
                                        seed=0))
        pareto_x, pareto_f = res.pareto_x, res.pareto_f

    hx, ox = T.exact_netlists(tnn)
    exact_area = T.tnn_hw_cost(tnn, hx, ox, interface=None).area_mm2
    print(f"[phase3] Pareto front ({len(pareto_x)} designs, "
          f"exact area {exact_area/100:.3f} cm^2):")
    best = None   # highest test accuracy, ties broken by smaller area
    for x, f in zip(pareto_x, pareto_f):
        hnl, onl = prob.decode(x)
        acc = float((T.predict_with_circuits(tnn, xb_te, hnl, onl)
                     == ds.y_test).mean())
        area = T.tnn_hw_cost(tnn, hnl, onl, interface=None).area_mm2
        print(f"  test_acc={acc:.3f}  area={area/100:.3f} cm^2 "
              f"({area/exact_area:.0%} of exact)")
        if best is None or (acc, -area) > (best[0], -best[1]):
            best = (acc, area, hnl, onl)

    # Phase 4: compile the winner -> emit RTL + report -> serve a stream
    acc, area, hnl, onl = best
    cc = lower_classifier(tnn, hnl, onl)
    paths = write_artifacts(cc, "artifacts", base=f"tnn_{dataset}",
                            dataset=dataset)
    rep = egfet_report(cc)
    print(f"[compile] winner acc={acc:.3f}: {cc.ir.n_gates} gates, "
          f"depth {cc.ir.depth}, {rep['total_area_mm2']:.2f} mm^2, "
          f"{rep['total_power_mw']:.3f} mW ({rep['power_source']})")
    print(f"[emit] {paths['verilog']}  {paths['report']}")
    engine = CircuitServingEngine(CircuitProgram.from_classifier(cc),
                                  max_batch=1024)
    engine.warmup()
    reps = max(1, 32768 // ds.x_test.shape[0])
    labels = engine.classify_stream(np.tile(ds.x_test, (reps, 1)))
    served_acc = float((labels == np.tile(ds.y_test, reps)).mean())
    s = engine.stats.summary()
    print(f"[serve] {s['n_readings']} readings at "
          f"{s['readings_per_s']:.0f} readings/s "
          f"(p50 {s['p50_ms']:.2f} ms/batch, served acc={served_acc:.3f})")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("dataset", nargs="?", default="cardio")
    ap.add_argument("--campaign", action="store_true",
                    help="run Phase 3 as a resumable island-model campaign")
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    a = ap.parse_args()
    main(a.dataset, campaign=a.campaign, islands=a.islands,
         ckpt_dir=a.ckpt_dir)
