"""Serving a fleet, end to end: emit -> manifest -> concurrent replay.

Trains quick exact TNNs on two Table-2 datasets, emits each as a servable
artifact bundle (Verilog + EGFET report + program npz, registered in the
emit dir's fleet.json manifest), then stands the whole directory up as a
multi-tenant `ClassifierFleet` and replays both held-out test streams
concurrently through the deadline-driven micro-batching scheduler.

The same replay is available as a CLI against any emit dir — including
`repro.evolve --emit-dir` campaign output:

    PYTHONPATH=src python -m repro.serve --emit-dir artifacts --replay all

Run:  PYTHONPATH=src python examples/serve_fleet.py [out_dir]
"""
import sys

import numpy as np

from repro.compile import lower_classifier, write_artifacts
from repro.core import tnn as T
from repro.data.tabular import make_dataset
from repro.serve import ClassifierFleet
from repro.serve.__main__ import replay_fleet

DATASETS = ("cardio", "breast_cancer")


def main(out_dir: str = "artifacts") -> dict:
    # emit: one servable bundle per tenant, all registered in fleet.json
    streams = {}
    for dataset in DATASETS:
        ds = make_dataset(dataset)
        tnn = T.train_tnn(ds, T.TNNTrainConfig(
            n_hidden=ds.spec.topology[1], epochs=6, lr=1e-2))
        cc = lower_classifier(tnn, *T.exact_netlists(tnn))
        paths = write_artifacts(cc, out_dir, base=f"tnn_{dataset}",
                                dataset=dataset)
        streams[f"tnn_{dataset}"] = np.tile(
            ds.x_test, (max(1, 1024 // ds.x_test.shape[0] + 1), 1))[:1024]
        print(f"[emit] tnn_{dataset}: acc={tnn.test_acc:.3f} "
              f"gates={cc.ir.n_gates} -> {paths['program']}")

    # serve: the manifest is the fleet
    fleet = ClassifierFleet.from_emit_dir(out_dir, backends="swar",
                                          max_batch=256, deadline_ms=250.0)
    try:
        report = replay_fleet(fleet, streams, producers=4)
    finally:
        fleet.shutdown(drain=True)
    for name, row in report["tenants"].items():
        print(f"[serve] {name}: {row['n_readings']} readings, "
              f"{row['readings_per_s']:.0f} readings/s, req p99 "
              f"{row['req_p99_ms']:.2f} ms, slo_miss={row['slo_miss']}, "
              f"labels_match={row['labels_match_offline']}")
    assert report["labels_match_offline"], "fleet diverged from offline"
    return report


if __name__ == "__main__":
    main(*sys.argv[1:2])
