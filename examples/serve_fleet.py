"""Serving a fleet, end to end: emit -> manifest -> serve -> replay.

Trains quick exact TNNs on two Table-2 datasets, emits each as a servable
artifact bundle (Verilog + EGFET report + sha256-checked program npz,
registered in the emit dir's fleet.json manifest), then exercises both
halves of the unified `repro.serve` stack:

  1. **in-process** — stands the directory up as a multi-tenant
     `ClassifierFleet` (2 engine replicas per tenant, least-loaded
     routing) and replays both held-out test streams concurrently through
     the deadline-driven micro-batching scheduler;
  2. **over the wire** — starts the asyncio socket server on the same
     fleet and replays again through `FleetClient`, every reading crossing
     the length-prefixed binary protocol, then hot-reloads the manifest
     through the RELOAD round-trip.

The same flows are available as CLIs against any emit dir — including
`repro.evolve --emit-dir` campaign output:

    PYTHONPATH=src python -m repro.serve serve  --emit-dir artifacts --watch
    PYTHONPATH=src python -m repro.serve replay --emit-dir artifacts \
        --connect 127.0.0.1:7341 --replay all

Run:  PYTHONPATH=src python examples/serve_fleet.py [out_dir]
"""
import sys

import numpy as np

from repro.compile import lower_classifier, write_artifacts
from repro.core import tnn as T
from repro.data.tabular import make_dataset
from repro.serve import ClassifierFleet
from repro.serve.__main__ import replay_client, replay_fleet
from repro.serve.client import FleetClient
from repro.serve.server import FleetServer

DATASETS = ("cardio", "breast_cancer")


def main(out_dir: str = "artifacts") -> dict:
    # emit: one servable bundle per tenant, all registered in fleet.json
    streams = {}
    for dataset in DATASETS:
        ds = make_dataset(dataset)
        tnn = T.train_tnn(ds, T.TNNTrainConfig(
            n_hidden=ds.spec.topology[1], epochs=6, lr=1e-2))
        cc = lower_classifier(tnn, *T.exact_netlists(tnn))
        paths = write_artifacts(cc, out_dir, base=f"tnn_{dataset}",
                                dataset=dataset, replicas=2)
        streams[f"tnn_{dataset}"] = np.tile(
            ds.x_test, (max(1, 1024 // ds.x_test.shape[0] + 1), 1))[:1024]
        print(f"[emit] tnn_{dataset}: acc={tnn.test_acc:.3f} "
              f"gates={cc.ir.n_gates} -> {paths['program']}")

    # serve: the manifest is the fleet (replica hints come from the rows)
    # 500 ms budget: generous enough that the socket replay's submission
    # ramp (per-reading frames from Python producers) stays inside SLO
    fleet = ClassifierFleet.from_emit_dir(out_dir, backends="swar",
                                          max_batch=256, deadline_ms=500.0)
    server = FleetServer(fleet, watch_manifest=True)
    try:
        report = replay_fleet(fleet, streams, producers=4)
        for name, row in report["tenants"].items():
            print(f"[serve/inproc] {name}: {row['n_readings']} readings on "
                  f"{row['replicas']} replicas, "
                  f"{row['readings_per_s']:.0f} readings/s, req p99 "
                  f"{row['req_p99_ms']:.2f} ms, slo_miss={row['slo_miss']}, "
                  f"labels_match={row['labels_match_offline']}")
        assert report["labels_match_offline"], "fleet diverged from offline"

        # the same replay, through the socket transport
        host, port = server.start_background()
        with FleetClient(host, port) as client:
            wire = replay_client(client, fleet, streams, producers=4)
            for name, row in wire["tenants"].items():
                print(f"[serve/socket] {name}: {row['readings']} readings, "
                      f"req p99 {row.get('req_p99_ms', 0):.2f} ms, "
                      f"slo_miss={row['slo_miss']}, "
                      f"shed={row.get('n_shed', 0)}, "
                      f"labels_match={row['labels_match_offline']}")
            assert wire["labels_match_offline"], "socket diverged from offline"
            actions = client.reload()       # manifest hot-reload round-trip
            print(f"[serve/socket] manifest gen {actions['generation']}: "
                  f"nothing to move ({actions['added'] or '-'} added)")
    finally:
        server.stop()
        fleet.shutdown(drain=True)
    return report


if __name__ == "__main__":
    main(*sys.argv[1:2])
